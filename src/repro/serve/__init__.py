"""Query-serving layer over the join-engine facade (DESIGN.md §2.9).

The paper's thesis — caching subtree results pays off when joins *recur* —
only pays across queries if something outlives one engine object.  This
package is that something:

* :mod:`canonical`  — canonical labeling of CQ shapes/TDs, so isomorphic
  queries derive the same plan-cache key;
* :mod:`plancache`  — the compile-once plan cache: one long-lived
  :class:`~repro.core.cached_frontier.JaxCachedTrieJoin` per canonical
  ``(CQ shape, TD, order, JoinEngineConfig)``, its tier-2 tables staying
  warm across queries;
* :mod:`persist`    — versioned on-disk snapshots of the plan cache's
  tier-2 payload/count tables plus the kernel-autotune sidecar entries,
  so warmth survives the *process* (corrupt file → cold start, never an
  error);
* :mod:`session`    — the admission/queueing session layer: many
  concurrent clients ride ``evaluate_stream`` through one device-serial
  worker, bounded in-flight sessions, graceful rejection with retry-after.

Entry point: ``repro.core.engine.serve(db)`` or :class:`JoinServer` here.
"""
from .canonical import canonical_cq, canonical_td, config_key
from .plancache import CachedPlan, PlanCache
from .persist import SNAPSHOT_VERSION, load_snapshot, save_snapshot
from .session import JoinServer, Session, SessionRejected

__all__ = [
    "canonical_cq", "canonical_td", "config_key",
    "CachedPlan", "PlanCache",
    "SNAPSHOT_VERSION", "load_snapshot", "save_snapshot",
    "JoinServer", "Session", "SessionRejected",
]

"""Canonical labeling of query shapes — the plan-cache key derivation.

Two queries that differ only by a variable renaming (and/or atom
reordering) are the *same join* up to output column names; the serving
layer must hand both the same compiled plan.  ``canonical_cq`` computes a
canonical form of a :class:`~repro.core.cq.CQ`: a renaming of its
variables to ``v0..v{n-1}`` plus a sorted atom tuple that is identical
for every isomorphic input.  The algorithm is the classic
color-refinement + individualization scheme specialized to query
hypergraphs:

1. **Initial colors**: each variable's multiset of occurrences
   ``(relation, arity, position)``.
2. **Refinement (1-WL)**: iterate ``color(v) <- (color(v), sorted multiset
   of (relation, position, colors of the atom's full var tuple)))`` to a
   fixpoint.  Colors are canonical integers (ranks of sorted color
   values), so they are comparable *across* isomorphic queries.
3. **Minimal serialization**: among all orderings that list color classes
   in rank order and permute only within a class, pick the one whose
   sorted atom tuple is lexicographically minimal.  Isomorphic queries
   enumerate the same candidate set, hence agree on the minimum.

Step 3 is exponential in the largest color-class sizes (``∏ |class|!``);
queries are tiny (the paper's families top out around 10 variables) and
refinement usually splits everything, but a pathological input (e.g. a
large star's interchangeable rays — where any within-class order yields
the same key anyway, except the search cannot know that in general) is
cut off by ``budget``: past it we fall back to a *deterministic but not
isomorphism-invariant* order (first-occurrence within class).  The
fallback only costs plan-cache *sharing* between renamed copies of such
queries — never correctness, because a key is a faithful serialization of
the query: equal keys always mean genuinely isomorphic queries.

``canonical_td`` canonicalizes a tree decomposition *under* the query's
variable renaming (children sorted by their canonical subtree), and
``config_key`` serializes a :class:`JoinEngineConfig`.  The triple is the
plan-cache key.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.cq import CQ, Atom
from ..core.td import TreeDecomposition

__all__ = ["canonical_cq", "canonical_td", "config_key", "rename_query",
           "DEFAULT_BUDGET"]

# max orderings enumerated by the exact minimal-serialization search
DEFAULT_BUDGET = 40_320  # 8!


def _refine(q: CQ) -> Dict[str, int]:
    """Color refinement to fixpoint; returns canonical integer colors
    (equal across isomorphic queries, by construction from relation
    names/positions/ranks only)."""
    variables = q.variables
    occ: Dict[str, List[Tuple[str, int, Atom]]] = {v: [] for v in variables}
    for a in q.atoms:
        for i, v in enumerate(a.vars):
            occ[v].append((a.relation, i, a))
    color_val = {v: tuple(sorted((r, len(a.vars), i)
                                 for r, i, a in occ[v]))
                 for v in variables}
    ranks = {c: i for i, c in enumerate(sorted(set(color_val.values())))}
    color = {v: ranks[color_val[v]] for v in variables}
    for _ in range(len(variables)):
        n_classes = len(set(color.values()))
        new_val = {}
        for v in variables:
            sig = sorted((r, i, tuple(color[u] for u in a.vars))
                         for r, i, a in occ[v])
            new_val[v] = (color[v], tuple(sig))
        ranks = {c: i for i, c in enumerate(sorted(set(new_val.values())))}
        color = {v: ranks[new_val[v]] for v in variables}
        if len(set(color.values())) == n_classes:
            break
    return color


def _serialize(q: CQ, pos: Dict[str, int]
               ) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
    return tuple(sorted((a.relation, tuple(pos[v] for v in a.vars))
                        for a in q.atoms))


def canonical_cq(q: CQ, budget: int = DEFAULT_BUDGET
                 ) -> Tuple[CQ, Dict[str, int], str]:
    """Returns ``(canonical query, position map, key string)``.

    ``position map`` sends each original variable to its canonical index
    ``i`` (canonical name ``v{i}``); the canonical query is ``q`` with
    variables renamed through it and atoms sorted.  The key string is the
    canonical query's serialization — equal keys iff the canonical forms
    coincide (always for isomorphic queries within ``budget``; see the
    module docstring for the over-budget fallback)."""
    variables = q.variables
    color = _refine(q)
    classes: List[List[str]] = []
    for rank in sorted(set(color.values())):
        classes.append([v for v in variables if color[v] == rank])
    n_orderings = 1
    for c in classes:
        n_orderings *= math.factorial(len(c))
        if n_orderings > budget:
            break
    if n_orderings <= budget:
        best: Optional[Tuple[tuple, Dict[str, int]]] = None
        for perms in itertools.product(
                *[itertools.permutations(c) for c in classes]):
            flat = [v for grp in perms for v in grp]
            pos = {v: i for i, v in enumerate(flat)}
            ser = _serialize(q, pos)
            if best is None or ser < best[0]:
                best = (ser, pos)
        assert best is not None
        ser, pos = best
    else:
        # deterministic fallback: classes in rank order, first-occurrence
        # within class (exact-repeat queries still share; renamed copies
        # of pathological shapes may not)
        first = {v: i for i, v in enumerate(variables)}
        flat = [v for c in classes for v in sorted(c, key=first.get)]
        pos = {v: i for i, v in enumerate(flat)}
        ser = _serialize(q, pos)
    canon = CQ(tuple(Atom(rel, tuple(f"v{i}" for i in idxs))
                     for rel, idxs in ser))
    key = ";".join(f"{rel}({','.join(f'v{i}' for i in idxs)})"
                   for rel, idxs in ser)
    return canon, pos, key


def rename_query(q: CQ, mapping: Dict[str, str]) -> CQ:
    """Rename variables through ``mapping`` (atom order preserved)."""
    return CQ(tuple(Atom(a.relation, tuple(mapping[v] for v in a.vars))
                    for a in q.atoms))


def canonical_td(td: TreeDecomposition, pos: Dict[str, int]
                 ) -> Tuple[TreeDecomposition, str]:
    """Canonicalize a TD under the query's canonical renaming: bags are
    renamed through ``pos``, children are ordered by their canonical
    subtree serialization, nodes renumbered in the resulting preorder.
    Returns the rebuilt TD (over ``v{i}`` names) and its key string.

    The rebuilt TD — not the caller's — parameterizes the cached engine,
    so two isomorphic ``(q, td)`` pairs whose TDs differ only by child
    order or node numbering lower to the *same* schedule."""

    def node_key(v: int):
        bag = tuple(sorted(pos[x] for x in td.bags[v]))
        return (bag, tuple(sorted(node_key(c) for c in td.children[v])))

    bags: List[frozenset] = []
    parent: List[int] = []

    def build(v: int, parent_idx: int) -> None:
        idx = len(bags)
        bags.append(frozenset(f"v{pos[x]}" for x in td.bags[v]))
        parent.append(parent_idx)
        for c in sorted(td.children[v], key=node_key):
            build(c, idx)

    build(td.root, -1)
    out = TreeDecomposition(bags, parent)
    return out, repr(node_key(td.root))


def config_key(config) -> str:
    """Stable serialization of a ``JoinEngineConfig`` (all fields are
    primitives, so a JSON dump with sorted keys is canonical)."""
    return json.dumps(dataclasses.asdict(config), sort_keys=True,
                      default=str)

"""Versioned on-disk snapshots of the serving layer's warm state.

One compressed ``.npz`` holds, per resident plan: the canonical query,
TD and order (enough to rebuild the engine in a fresh process), the
schedule signature it was lowered to, and every tier-2 table's exported
state — key/count planes, payload metadata, the slab arena *and its
host-side epoch* (``slab_bump``/``payload_flushes``; see
:meth:`DeviceCache.import_state` for why the epoch is load-bearing).
The kernel registry's measured autotune entries ride along in the same
manifest, so a fresh process also skips re-measuring EXPAND dispatch.

Failure discipline mirrors the autotune sidecar's: a missing, truncated,
corrupt or wrong-schema snapshot is a *fallback to cold*, never an error
— per plan (one bad plan record cannot poison the rest) and per table
(the cache layer's import validation cold-starts just the payload region
when the slab epoch is unusable).  Writes are atomic
(temp file + ``os.replace``), so a concurrent reader never sees a torn
snapshot.
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Dict, Optional

import numpy as np

from ..core.cq import CQ, Atom
from ..core.td import TreeDecomposition
from ..kernels import registry as _registry

__all__ = ["SNAPSHOT_VERSION", "save_snapshot", "load_snapshot"]

SNAPSHOT_VERSION = 1
_SCALARS = ("slab_bump", "payload_flushes", "tick")


def save_snapshot(path: str, plan_cache) -> str:
    """Write the plan cache's warm state to ``path``; returns ``path``."""
    manifest: Dict = {"version": SNAPSHOT_VERSION,
                      "cfg_key": plan_cache.cfg_key,
                      "autotune": _registry.autotune_entries(),
                      "plans": []}
    arrays: Dict[str, np.ndarray] = {}
    for i, entry in enumerate(plan_cache.entries()):
        states = entry.engine.cache.export_state()
        rec = {"atoms": [[a.relation, list(a.vars)]
                         for a in entry.cq.atoms],
               "bags": [sorted(b) for b in entry.td.bags],
               "parent": list(entry.td.parent),
               "order": list(entry.order),
               # original key components ("auto" when the writer's
               # clients let the planner choose) — the loader registers
               # under these so a fresh process's td=None lookups hit
               "td_key": entry.key[1],
               "order_key": entry.key[2],
               "schedule_sig": entry.schedule_sig,
               "tables": {}}
        for node, st in states.items():
            names = {}
            scal = {}
            for k, v in st.items():
                if k in _SCALARS:
                    scal[k] = int(v)
                else:
                    nm = f"p{i}_n{node}_{k}"
                    arrays[nm] = np.asarray(v)
                    names[k] = nm
            rec["tables"][str(node)] = {"arrays": names, **scal}
        manifest["plans"].append(rec)
    arrays["manifest"] = np.frombuffer(
        json.dumps(manifest).encode("utf-8"), np.uint8).copy()
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **arrays)
    os.replace(tmp, path)
    return path


def load_snapshot(path: str, plan_cache) -> Dict[str, int]:
    """Warm ``plan_cache`` from a snapshot written by :func:`save_snapshot`.

    For each persisted plan whose config matches the cache's, the engine
    is (re)built through ``plan_cache.restore`` — paying construction and
    compile once at load time instead of on the first client query, and
    registering under the writer's original key so ``td=None`` client
    lookups hit — then its tier-2 tables adopt the persisted state.  Plans whose schedule
    signature no longer matches (a lowering change since the writer) are
    skipped cold.  Returns a summary dict; on any unreadable file:
    ``{"status": "cold", ...zeros}`` after a warning — never an
    exception."""
    out = {"status": "ok", "plans": 0, "tables": 0, "flushed": 0,
           "skipped": 0, "autotune": 0}
    try:
        with np.load(path) as z:
            manifest = json.loads(bytes(z["manifest"]).decode("utf-8"))
            if manifest.get("version") != SNAPSHOT_VERSION:
                raise ValueError(
                    f"snapshot version {manifest.get('version')!r} != "
                    f"{SNAPSHOT_VERSION}")
            out["autotune"] = _registry.merge_autotune_entries(
                manifest.get("autotune", []))
            if manifest.get("cfg_key") != plan_cache.cfg_key:
                # a different engine config keys different plans AND
                # different table geometry: only the autotune transfers
                out["status"] = "config-mismatch"
                return out
            plans = manifest.get("plans", [])
            if not isinstance(plans, list):
                raise TypeError("plans must be a list")
            for rec in plans:
                try:
                    _load_plan(z, rec, plan_cache, out)
                except Exception as e:
                    warnings.warn(
                        f"skipping one snapshot plan from {path}: {e}")
                    out["skipped"] += 1
    except Exception as e:
        warnings.warn(f"ignoring unreadable serve snapshot {path}: {e}")
        return {"status": "cold", "plans": 0, "tables": 0, "flushed": 0,
                "skipped": 0, "autotune": 0}
    return out


def _load_plan(z, rec: Dict, plan_cache, out: Dict[str, int]) -> None:
    cq = CQ(tuple(Atom(str(rel), tuple(str(v) for v in vs))
                  for rel, vs in rec["atoms"]))
    td = TreeDecomposition([frozenset(b) for b in rec["bags"]],
                           [int(p) for p in rec["parent"]])
    order = tuple(str(v) for v in rec["order"])
    entry, _resident = plan_cache.restore(
        cq, td, order,
        td_key=str(rec.get("td_key", "auto")),
        order_key=str(rec.get("order_key", "auto")))
    if entry.schedule_sig != rec.get("schedule_sig"):
        # the lowering changed since this snapshot was written: its table
        # state describes a different instruction stream — start cold
        out["skipped"] += 1
        return
    states: Dict[int, Dict[str, object]] = {}
    for node, trec in rec["tables"].items():
        st: Dict[str, object] = {k: z[nm]
                                 for k, nm in trec["arrays"].items()}
        for k in _SCALARS:
            if k in trec:
                st[k] = int(trec[k])
        states[int(node)] = st
    statuses = entry.engine.cache.import_state(states)
    out["plans"] += 1
    out["tables"] += sum(1 for s in statuses.values() if s == "ok")
    out["flushed"] += sum(1 for s in statuses.values() if s == "flushed")

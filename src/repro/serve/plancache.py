"""Compile-once plan cache: one warm engine per canonical query shape.

The facade's one-shot calls construct a fresh
:class:`~repro.core.cached_frontier.JaxCachedTrieJoin` per query, so TD
planning, trie construction, jit warm-up *and the tier-2 tables* die with
every call.  :class:`PlanCache` keeps the engine: queries are keyed by
``(canonical CQ, canonical TD, canonical order, JoinEngineConfig)`` (see
:mod:`canonical`), isomorphic queries map to the same entry, and a hit
returns an engine whose device caches are warm from every previous query
of that shape — the paper's recurring-subjoin payoff finally compounding
*across* queries.

The cached engine is built over the canonical variable names ``v{i}``;
``lookup`` also returns the requester's variable mapping so the caller
can relabel the engine's output order back to its own names (the tuples
themselves need no transformation — only the column names differ).

Eviction is LRU over entries with a ``max_plans`` bound (``max_plans=0``
disables caching: every lookup builds fresh — the benchmark's cold
regime).  Lookup/registration is lock-protected; *executing* a cached
engine is NOT thread-safe and must be serialized by the caller (the
session layer's single worker thread — the device is serial anyway).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.cached_frontier import JaxCachedTrieJoin
from ..core.cq import CQ
from ..core.db import Database
from ..core.decompose import choose_plan
from ..core.engine import CompileClock
from ..core.td import TreeDecomposition
from .canonical import canonical_cq, canonical_td, config_key

__all__ = ["CachedPlan", "PlanCache"]


def _default_config():
    from ..configs.paper_clftj import TPU_SERVE

    return TPU_SERVE


@dataclass
class CachedPlan:
    """One resident plan: the canonical query/TD/order and the long-lived
    engine compiled for them (its ``cache`` manager IS the cross-query
    tier-2 state that :mod:`persist` snapshots)."""

    key: Tuple[str, str, str, str]   # (q_key, td_key, order_key, cfg_key)
    cq: CQ                           # canonical query (v{i} names)
    td: TreeDecomposition            # canonical TD
    order: Tuple[str, ...]           # canonical order
    engine: JaxCachedTrieJoin
    schedule_sig: str                # Schedule.signature() at build time
    build_s: float = 0.0             # planning + construction seconds
    build_compile_s: float = 0.0     # jit compile seconds during build
    hits: int = 0
    queries: int = 0


class PlanCache:
    """LRU cache of :class:`CachedPlan` entries for one database."""

    def __init__(self, db: Database, config=None, max_plans: int = 64):
        self.db = db
        self.config = config if config is not None else _default_config()
        self.cfg_key = config_key(self.config)
        self.max_plans = int(max_plans)
        self._plans: "OrderedDict[tuple, CachedPlan]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    # -- key derivation ------------------------------------------------
    def _canonicalize(self, q: CQ,
                      td: Optional[TreeDecomposition],
                      order: Optional[Sequence[str]]):
        canon_q, pos, q_key = canonical_cq(q)
        if td is not None:
            ctd, td_key = canonical_td(td, pos)
        else:
            ctd, td_key = None, "auto"
        if order is not None:
            corder = tuple(f"v{pos[v]}" for v in order)
            order_key = ",".join(corder)
        else:
            corder, order_key = None, "auto"
        key = (q_key, td_key, order_key, self.cfg_key)
        return canon_q, pos, ctd, corder, key

    # -- lookup --------------------------------------------------------
    def lookup(self, q: CQ, td: Optional[TreeDecomposition] = None,
               order: Optional[Sequence[str]] = None
               ) -> Tuple[CachedPlan, bool, Dict[str, int]]:
        """Resolve ``(q, td, order)`` to a plan entry.

        Returns ``(entry, hit, pos)`` where ``pos`` maps the requester's
        variable names to canonical indices (requester column for
        canonical ``v{i}`` = the variable with ``pos[var] == i``)."""
        canon_q, pos, ctd, corder, key = self._canonicalize(q, td, order)
        with self._lock:
            entry = self._plans.get(key)
            if entry is not None:
                self._plans.move_to_end(key)
                self.hits += 1
                entry.hits += 1
                return entry, True, pos
            self.misses += 1
        # build OUTSIDE the lock (compiles can be slow); duplicate builds
        # of the same key race benignly — last registration wins
        entry = self._build(canon_q, ctd, corder, key)
        with self._lock:
            if self.max_plans > 0:
                self._plans[key] = entry
                self._plans.move_to_end(key)
                while len(self._plans) > self.max_plans:
                    self._plans.popitem(last=False)
        return entry, False, pos

    def restore(self, q: CQ, td: TreeDecomposition,
                order: Sequence[str], td_key: str, order_key: str
                ) -> Tuple[CachedPlan, bool]:
        """Rebuild a snapshot-persisted plan and register it under the
        *writer's* key components.

        The snapshot stores the explicit canonical TD/order (so the
        engine rebuilds without re-planning) **and** the original
        ``td_key``/``order_key`` — which are ``"auto"`` when the writer's
        clients let the planner choose.  Registering under the stored key
        rather than the explicit-TD key is what makes a fresh process's
        first ``td=None`` query *hit* the loaded plan instead of building
        a cold twin next to it.  Returns ``(entry, already_resident)``."""
        canon_q, _pos, ctd, corder, key = self._canonicalize(q, td, order)
        key = (key[0], td_key, order_key, self.cfg_key)
        with self._lock:
            entry = self._plans.get(key)
            if entry is not None:
                self._plans.move_to_end(key)
                return entry, True
        entry = self._build(canon_q, ctd, corder, key)
        with self._lock:
            if self.max_plans > 0:
                self._plans[key] = entry
                self._plans.move_to_end(key)
                while len(self._plans) > self.max_plans:
                    self._plans.popitem(last=False)
        return entry, False

    def _build(self, canon_q: CQ, ctd: Optional[TreeDecomposition],
               corder: Optional[Tuple[str, ...]], key: tuple) -> CachedPlan:
        cfg = self.config
        t0 = time.perf_counter()
        if ctd is None or corder is None:
            td_, order_ = choose_plan(canon_q, self.db.stats(),
                                      max_adhesion=cfg.max_adhesion,
                                      limit=cfg.td_limit)
            ctd = ctd if ctd is not None else td_
            corder = corder if corder is not None else tuple(order_)
        with CompileClock() as cc:
            engine = JaxCachedTrieJoin(
                canon_q, ctd, corder, self.db,
                capacity=cfg.frontier_capacity, dedup=cfg.dedup,
                impl=cfg.impl, cache=cfg.cache_config(),
                expand_kernel=cfg.expand_kernel,
                emit_in_flight=cfg.emit_in_flight)
        return CachedPlan(key=key, cq=canon_q, td=ctd, order=tuple(corder),
                          engine=engine,
                          schedule_sig=engine.schedule.signature(),
                          build_s=time.perf_counter() - t0,
                          build_compile_s=cc.total)

    # -- introspection -------------------------------------------------
    def entries(self) -> List[CachedPlan]:
        with self._lock:
            return list(self._plans.values())

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"plans": len(self._plans), "hits": self.hits,
                    "misses": self.misses, "max_plans": self.max_plans}

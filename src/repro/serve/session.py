"""Concurrent query sessions over one device-serial worker.

Many clients, one device: :class:`JoinServer` admits up to
``max_sessions`` in-flight sessions (submit past the bound raises
:class:`SessionRejected` carrying a load-derived ``retry_after_s``), and
a single worker thread executes admitted sessions FIFO — the engines are
host-stateful and the device is serial, so parallel execution would only
interleave destructively.  Concurrency that *does* pay lives elsewhere:
per-session result queues are bounded (a slow consumer back-pressures
the worker, not the device memory), result blocks leave the device
through ``evaluate_stream``'s async-copy queue, and every client thread
drains its own :class:`Session` independently.

Per-session accounting keeps the repo's discipline: a
:class:`~repro.core.hostsync.SyncCounter` (thread-local, so only the
worker's syncs land in it) and a
:class:`~repro.core.engine.CompileClock` wrap each execution, engine
counters are reported as per-query *deltas* (the plan-cached engine
accumulates across queries), and ``plan_cache_hit`` rides the counters
into :class:`~repro.core.engine.Result`.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

from ..core.cq import CQ
from ..core.db import Database
from ..core.engine import CompileClock, Result
from ..core.hostsync import SyncCounter
from ..core.td import TreeDecomposition
from .plancache import PlanCache

__all__ = ["JoinServer", "Session", "SessionRejected"]

# engine counters that are levels, not monotonic totals — reported
# absolute in per-query deltas (mirrors benchmarks/common.run_jax_eval)
_LEVELS = ("tier2_slab_rows", "tier2_slots")


class SessionRejected(RuntimeError):
    """Admission refused: the server is at its in-flight session bound.

    ``retry_after_s`` is the server's load-derived backoff hint (recent
    mean query latency × queue depth)."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


class _Cancelled(Exception):
    pass


class Session:
    """One admitted query: a bounded block queue the worker fills and the
    client drains (``blocks()``), plus the finished :class:`Result`
    (``result()``).  ``order`` is the *requester-facing* column order —
    the cached engine's canonical order relabeled back to the client's
    variable names."""

    _SENTINEL = object()

    def __init__(self, sid: int, q: CQ, mode: str,
                 td: Optional[TreeDecomposition],
                 order: Optional[Sequence[str]], block_queue: int):
        self.id = sid
        self.query = q
        self.mode = mode
        self.td_arg = td
        self.order_arg = order
        self.state = "queued"
        self.order: Optional[Tuple[str, ...]] = None
        self.plan_cache_hit: Optional[bool] = None
        self.sync: Optional[SyncCounter] = None
        self.op_runs: Optional[Dict[str, int]] = None
        self._blocks: "queue.Queue" = queue.Queue(maxsize=max(1, block_queue))
        self._done = threading.Event()
        self._order_ready = threading.Event()
        self._cancel = threading.Event()
        self._result: Optional[Result] = None
        self._error: Optional[BaseException] = None

    # -- client side ---------------------------------------------------
    def blocks(self) -> Iterator[np.ndarray]:
        """Yield result morsels (k, n int32, columns = ``order``) in
        production order; returns when the session completes.  Raises the
        session's error, if any, after the produced prefix."""
        while True:
            item = self._blocks.get()
            if item is self._SENTINEL:
                if self._error is not None:
                    raise self._error
                return
            yield item

    def result(self, timeout: Optional[float] = None) -> Result:
        """Block until the session finishes; raises its error if it
        failed.  For streaming sessions the result only lands once the
        worker has pushed every block, so a client must drain
        ``blocks()`` (or ``cancel()``) before/while waiting."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"session {self.id} still running")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def cancel(self) -> None:
        """Abandon the session: the worker stops producing at the next
        block boundary (engine stats still finalize) and the queue is
        drained so a blocked worker wakes up."""
        self._cancel.set()
        try:
            while True:
                self._blocks.get_nowait()
        except queue.Empty:
            pass

    def wait_order(self, timeout: Optional[float] = None
                   ) -> Tuple[str, ...]:
        """Block until the worker has resolved the plan (order known)."""
        if not self._order_ready.wait(timeout):
            raise TimeoutError(f"session {self.id} not yet planned")
        assert self.order is not None
        return self.order

    # -- worker side ---------------------------------------------------
    def _push(self, block: np.ndarray) -> None:
        while True:
            if self._cancel.is_set():
                raise _Cancelled()
            try:
                self._blocks.put(block, timeout=0.05)
                return
            except queue.Full:
                continue

    def _finish(self, result: Optional[Result],
                error: Optional[BaseException]) -> None:
        self._result = result
        self._error = error
        self.state = ("done" if error is None else
                      "cancelled" if isinstance(error, _Cancelled)
                      else "failed")
        self._order_ready.set()
        self._done.set()
        while True:  # sentinel must land even past a full queue
            if self._cancel.is_set():
                try:
                    while True:
                        self._blocks.get_nowait()
                except queue.Empty:
                    pass
            try:
                self._blocks.put(self._SENTINEL, timeout=0.05)
                return
            except queue.Full:
                continue


class JoinServer:
    """Long-lived query server: plan cache + persistent tier-2 tables +
    admission-bounded concurrent sessions (DESIGN.md §2.9).

    ``submit``/``evaluate_stream`` return a :class:`Session`;
    ``count``/``evaluate`` are synchronous conveniences.  ``config`` is a
    :class:`~repro.configs.paper_clftj.JoinEngineConfig` (default
    ``TPU_SERVE``).  ``save_snapshot``/``load_snapshot`` persist the warm
    caches across processes (:mod:`persist`)."""

    def __init__(self, db: Database, config=None, *,
                 max_sessions: int = 8, max_plans: int = 64,
                 block_queue: int = 64):
        self.plan_cache = PlanCache(db, config, max_plans=max_plans)
        self.db = db
        self.max_sessions = int(max_sessions)
        self.block_queue = int(block_queue)
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._pending: "deque[Session]" = deque()
        self._exec_lock = threading.Lock()  # engines are single-threaded
        self._closed = False
        self._next_sid = 0
        self.in_flight = 0
        self.in_flight_high_water = 0
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0
        self._ewma_s: Optional[float] = None
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="join-server-worker")
        self._worker.start()

    # -- admission -----------------------------------------------------
    def submit(self, q: CQ, mode: str = "stream",
               td: Optional[TreeDecomposition] = None,
               order: Optional[Sequence[str]] = None) -> Session:
        """Admit one query session (``mode``: "stream" | "evaluate" |
        "count").  Raises :class:`SessionRejected` past the in-flight
        bound — in-flight means admitted and not yet finished, so slow
        *consumers* hold their slot (back-pressure reaches admission)."""
        if mode not in ("stream", "evaluate", "count"):
            raise ValueError(f"unknown session mode {mode!r}")
        with self._wake:
            if self._closed:
                raise RuntimeError("server is closed")
            if self.in_flight >= self.max_sessions:
                self.rejected += 1
                depth = self.in_flight + len(self._pending)
                retry = (self._ewma_s or 0.05) * max(1, depth)
                raise SessionRejected(
                    f"at capacity ({self.in_flight}/{self.max_sessions} "
                    f"sessions in flight); retry in ~{retry:.3f}s", retry)
            self.in_flight += 1
            self.in_flight_high_water = max(self.in_flight_high_water,
                                            self.in_flight)
            self.submitted += 1
            self._next_sid += 1
            sess = Session(self._next_sid, q, mode, td, order,
                           self.block_queue)
            self._pending.append(sess)
            self._wake.notify()
        return sess

    # -- synchronous conveniences --------------------------------------
    def count(self, q: CQ, td=None, order=None) -> Result:
        return self.submit(q, "count", td, order).result()

    def evaluate(self, q: CQ, td=None, order=None) -> Result:
        return self.submit(q, "evaluate", td, order).result()

    def evaluate_stream(self, q: CQ, td=None, order=None) -> Session:
        return self.submit(q, "stream", td, order)

    # -- persistence (serialized against query execution) --------------
    def save_snapshot(self, path: str) -> str:
        from .persist import save_snapshot

        with self._exec_lock:
            return save_snapshot(path, self.plan_cache)

    def load_snapshot(self, path: str) -> Dict[str, int]:
        from .persist import load_snapshot

        with self._exec_lock:
            return load_snapshot(path, self.plan_cache)

    # -- worker --------------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._wake:
                while not self._pending and not self._closed:
                    self._wake.wait()
                if self._closed and not self._pending:
                    return
                sess = self._pending.popleft()
            self._execute(sess)

    def _execute(self, sess: Session) -> None:
        t0 = time.perf_counter()
        sess.state = "running"
        result: Optional[Result] = None
        error: Optional[BaseException] = None
        try:
            with self._exec_lock:
                entry, hit, pos = self.plan_cache.lookup(
                    sess.query, sess.td_arg, sess.order_arg)
                inv = {f"v{i}": v for v, i in pos.items()}
                sess.order = tuple(inv[c] for c in entry.order)
                sess.plan_cache_hit = hit
                sess._order_ready.set()
                eng = entry.engine
                entry.queries += 1
                s0 = dict(eng.stats)
                tuples = None
                sc = SyncCounter()
                cc = CompileClock()
                with cc, sc:
                    if sess.mode == "count":
                        n = eng.count()
                    elif sess.mode == "evaluate":
                        blocks = list(eng.evaluate())
                        tuples = (np.concatenate(blocks, axis=0) if blocks
                                  else np.zeros((0, len(entry.order)),
                                                np.int32))
                        n = tuples.shape[0]
                    else:
                        n = 0
                        gen = eng.evaluate_stream()
                        try:
                            for block in gen:
                                n += block.shape[0]
                                sess._push(block)
                        finally:
                            gen.close()  # always fold stats (_finalize)
                sess.sync = sc
                sess.op_runs = dict(getattr(eng, "last_executor", None)
                                    and eng.last_executor.op_runs or {})
                s1 = dict(eng.stats)
            counters = {k: v - s0.get(k, 0) for k, v in s1.items()
                        if isinstance(v, int) and k not in _LEVELS}
            counters.update({k: s1[k] for k in _LEVELS if k in s1})
            counters["plan_cache_hit"] = int(hit)
            t1 = time.perf_counter()
            # a miss paid the plan build inside this window (the lookup);
            # split it out the way the one-shot facade does, so cold/warm
            # latency decompositions stay comparable
            plan_s = 0.0 if hit else entry.build_s
            compile_s = cc.total + (0.0 if hit else entry.build_compile_s)
            wall = t1 - t0
            result = Result(
                count=n, tuples=tuples, algorithm="clftj", backend="jax",
                order=sess.order, td=entry.td, counters=counters,
                wall_s=wall, plan_s=plan_s, compile_s=compile_s,
                exec_s=max(0.0, wall - plan_s - compile_s))
        except BaseException as e:  # noqa: BLE001 — reported to the client
            error = e
        finally:
            with self._wake:
                self.in_flight -= 1
                if error is None or isinstance(error, _Cancelled):
                    self.completed += 1
                else:
                    self.failed += 1
                dt = time.perf_counter() - t0
                self._ewma_s = (dt if self._ewma_s is None
                                else 0.7 * self._ewma_s + 0.3 * dt)
            sess._finish(result, error)

    # -- lifecycle -----------------------------------------------------
    def stats(self) -> Dict[str, object]:
        with self._lock:
            out = {"submitted": self.submitted, "completed": self.completed,
                   "failed": self.failed, "rejected": self.rejected,
                   "in_flight": self.in_flight,
                   "in_flight_high_water": self.in_flight_high_water,
                   "queued": len(self._pending),
                   "max_sessions": self.max_sessions}
        out["plan_cache"] = self.plan_cache.stats()
        return out

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Drain pending sessions, then stop the worker."""
        with self._wake:
            self._closed = True
            self._wake.notify_all()
        self._worker.join(timeout)

    def __enter__(self) -> "JoinServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

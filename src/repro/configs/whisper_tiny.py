"""whisper-tiny — encoder-decoder; conv frontend is a stub supplying frame
embeddings (input_specs provides them precomputed) [arXiv:2212.04356]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, head_dim=64,
    norm="layernorm", act="gelu",
    encoder_decoder=True, n_encoder_layers=4, encoder_seq=1500,
    block_pattern=("dec",),
)

"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427 (Griffin)].  26 layers = 8 x (rglru, rglru, local) + 2."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "local"),
    window=2048, d_rnn=2560, conv_width=4,
)

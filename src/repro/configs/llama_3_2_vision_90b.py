"""llama-3.2-vision-90b — dense GQA with cross-attention image layers every
5th layer; vision frontend is a stub supplying patch embeddings
[hf:meta-llama/Llama-3.2-90B-Vision]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    block_pattern=("attn", "attn", "attn", "attn", "cross"),
    n_image_tokens=1601,
)

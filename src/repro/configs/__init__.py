"""Assigned-architecture registry: --arch <id> resolves here."""
from typing import Dict

from .base import ArchConfig
from .minitron_8b import CONFIG as minitron_8b
from .stablelm_12b import CONFIG as stablelm_12b
from .qwen2_5_3b import CONFIG as qwen2_5_3b
from .yi_6b import CONFIG as yi_6b
from .recurrentgemma_2b import CONFIG as recurrentgemma_2b
from .qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from .phi3_5_moe_42b_a6_6b import CONFIG as phi3_5_moe_42b_a6_6b
from .llama_3_2_vision_90b import CONFIG as llama_3_2_vision_90b
from .rwkv6_7b import CONFIG as rwkv6_7b
from .whisper_tiny import CONFIG as whisper_tiny

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in [
        minitron_8b, stablelm_12b, qwen2_5_3b, yi_6b, recurrentgemma_2b,
        qwen3_moe_235b_a22b, phi3_5_moe_42b_a6_6b, llama_3_2_vision_90b,
        rwkv6_7b, whisper_tiny,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[: -len("-smoke")]].smoke()
    return ARCHS[name]

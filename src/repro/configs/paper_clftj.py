"""The paper's own artifact: CLFTJ join-engine configuration presets.

These mirror the knobs of the paper's implementation (§5.1): cache bound
(Fig 10), admission threshold (§3.4), adhesion-dimension cap (the paper's
unordered_map supports <= 2 key attributes), TD-enumeration budget (§4.3) —
plus the TPU-engine knobs (frontier capacity, tier-1 dedup, and the tier-2
device-cache policy/associativity/budget of ``core/cache.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..core.cache import CacheConfig


@dataclass(frozen=True)
class JoinEngineConfig:
    # planning (paper §4)
    max_adhesion: int = 2          # separator-size bound in TD enumeration
    td_limit: int = 24             # TDs scored before picking one
    # host reference engine (paper Fig 2)
    support_threshold: int = 1     # §3.4 admission policy
    capacity: Optional[int] = None  # Fig 10 dynamic cache bound (None = inf)
    evict: str = "none"            # none | lru | cost
    # vectorized engine (DESIGN.md §2)
    frontier_capacity: int = 1 << 16
    cache_slots: int = 1 << 16     # tier-2 table slots (initial)
    cache_policy: str = "direct"   # direct | setassoc | costaware
    cache_assoc: int = 4           # ways per set (setassoc/costaware)
    cache_dynamic: bool = False    # sizing controller on/off
    cache_budget: Optional[int] = None  # max total slots across node tables
    cache_payloads: bool = False   # eval-mode row-block replay (DESIGN §2.6)
    payload_rows: int = 1 << 15    # slab arena rows per node table
    dedup: bool = True             # tier-1 intra-chunk dedup
    impl: str = "bsearch"          # bsearch | pallas (bounded-search flavor)
    expand_kernel: str = "auto"    # auto | pallas | xla (DESIGN.md §2.7)
    emit_in_flight: int = 8        # streaming-emit async-copy bound (§2.8)

    def cache_config(self) -> CacheConfig:
        """Tier-2 device-cache config for the vectorized engine."""
        return CacheConfig(policy=self.cache_policy, slots=self.cache_slots,
                           assoc=self.cache_assoc, dynamic=self.cache_dynamic,
                           budget=self.cache_budget,
                           cache_payloads=self.cache_payloads,
                           payload_rows=self.payload_rows)


PAPER_FAITHFUL = JoinEngineConfig(
    # "We first consider caches that store every intermediate result" (§5.1)
    support_threshold=1, capacity=None)

BOUNDED_100K = JoinEngineConfig(capacity=100_000)   # Fig 10 mid-point
TPU_DEFAULT = JoinEngineConfig()

# Flexible-cache presets (tier-2 policy sweep; DESIGN.md §2.3)
TPU_SETASSOC = JoinEngineConfig(cache_policy="setassoc", cache_assoc=4)
TPU_COST_AWARE = JoinEngineConfig(cache_policy="costaware", cache_assoc=4)
TPU_ADAPTIVE = JoinEngineConfig(      # Fig 10's size knob made adaptive
    cache_policy="setassoc", cache_assoc=4, cache_slots=1 << 10,
    cache_dynamic=True, cache_budget=1 << 18)
TPU_EVAL_REPLAY = JoinEngineConfig(   # §3.4 evaluation: replay-on-hit
    cache_policy="setassoc", cache_assoc=8, cache_slots=1 << 14,
    cache_payloads=True, payload_rows=1 << 17)
TPU_FUSED_EXPAND = JoinEngineConfig(  # single-launch EXPAND (DESIGN §2.7)
    expand_kernel="pallas")
TPU_STREAM_EMIT = JoinEngineConfig(   # §2.8 streaming evaluation: replay-
    # capable tier 2 + a deeper async-emit window (result blocks stream
    # while the next morsel expands; raise the bound when result blocks
    # are small relative to device memory)
    cache_policy="setassoc", cache_assoc=8, cache_slots=1 << 14,
    cache_payloads=True, payload_rows=1 << 17, emit_in_flight=16)
TPU_SERVE = JoinEngineConfig(         # repro/serve default (DESIGN §2.9):
    # long-lived engines answering many queries — associative tables so
    # cross-query keys don't conflict-thrash, payload replay on so warm
    # queries splice instead of recomputing, streaming emit for sessions
    cache_policy="setassoc", cache_assoc=8, cache_slots=1 << 14,
    cache_payloads=True, payload_rows=1 << 17, emit_in_flight=8)

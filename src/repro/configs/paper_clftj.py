"""The paper's own artifact: CLFTJ join-engine configuration presets.

These mirror the knobs of the paper's implementation (§5.1): cache bound
(Fig 10), admission threshold (§3.4), adhesion-dimension cap (the paper's
unordered_map supports <= 2 key attributes), TD-enumeration budget (§4.3) —
plus the TPU-engine knobs (frontier capacity, tier-1 dedup).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class JoinEngineConfig:
    # planning (paper §4)
    max_adhesion: int = 2          # separator-size bound in TD enumeration
    td_limit: int = 24             # TDs scored before picking one
    # host reference engine (paper Fig 2)
    support_threshold: int = 1     # §3.4 admission policy
    capacity: Optional[int] = None  # Fig 10 dynamic cache bound (None = inf)
    evict: str = "none"            # none | lru
    # vectorized engine (DESIGN.md §2)
    frontier_capacity: int = 1 << 16
    cache_slots: int = 1 << 16     # tier-2 direct-mapped table slots
    dedup: bool = True             # tier-1 intra-chunk dedup
    impl: str = "bsearch"          # bsearch | pallas


PAPER_FAITHFUL = JoinEngineConfig(
    # "We first consider caches that store every intermediate result" (§5.1)
    support_threshold=1, capacity=None)

BOUNDED_100K = JoinEngineConfig(capacity=100_000)   # Fig 10 mid-point
TPU_DEFAULT = JoinEngineConfig()

"""Architecture configuration schema for the LM substrate.

Every assigned architecture is an ``ArchConfig`` instance (one module per
arch under ``repro/configs``).  The config is deliberately explicit — layer
pattern, GQA widths, MoE routing, recurrent block dims — so that the dry-run
and roofline math can be derived from it without touching model code.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu (swiglu) | gelu
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- layer pattern (repeated; remainder layers appended unrolled) ---
    block_pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None   # sliding window for "local" blocks
    d_rnn: Optional[int] = None    # RG-LRU width
    conv_width: int = 4
    # --- vlm ---
    n_image_tokens: int = 0
    # --- enc-dec (audio) ---
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0
    # --- attention-free (rwkv) ---
    rwkv_head_dim: int = 64
    # --- training knobs ---
    remat_policy: str = "full"     # none | full | dots
    dtype_compute: str = "bfloat16"
    max_seq: int = 4096            # default trained context (shapes override)
    # cost-probe mode: unroll every scan (layers, flash blocks, loss chunks)
    # so compiled.cost_analysis() counts true totals — XLA counts a while
    # body ONCE regardless of trip count (see launch/costprobe.py)
    cost_exact: bool = False
    # Megatron-style sequence parallelism: residuals/LN constrained to a
    # sequence-sharded layout between blocks, turning per-layer activation
    # all-reduces into reduce-scatter+all-gather pairs (half the bytes) and
    # shrinking saved activations by the model-axis factor.  Only meaningful
    # under a mesh context (dry-run / production); see §Perf.
    seq_shard: bool = False

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_rem_layers(self) -> int:
        return self.n_layers % len(self.pattern)

    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.pattern
        return p * self.n_groups + p[: self.n_rem_layers]

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count of this config (used for 6ND model FLOPs).

        MoE counts all experts; ``active_param_count`` counts routed-active.
        """
        from ..models.specs import model_specs, count_params
        return count_params(model_specs(self))

    def active_param_count(self) -> int:
        total = self.param_count()
        if self.n_experts and self.top_k:
            from ..models.specs import model_specs, count_params, expert_params
            all_e, per_e = expert_params(self)
            total = total - all_e + self.top_k * per_e * len(
                [k for k in self.layer_kinds() if k == "moe"])
        return total

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        pat_len = len(self.pattern)
        n_layers = max(pat_len, min(2 * pat_len, 4))
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab=256,
            d_rnn=64 if self.d_rnn else None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            window=16 if self.window else None,
            n_image_tokens=8 if self.n_image_tokens else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=16 if self.encoder_seq else 0,
            rwkv_head_dim=16,
            max_seq=32,
        )

"""Deterministic, shardable, checkpointable LM token pipeline.

The synthetic stream is *learnable*: token_{i+1} = (a·token_i + c) mod V
with probability 1-ε, uniform noise otherwise — so a trained model's loss
drops visibly below ln(V) toward the noise entropy (used by the train_lm
example).  Batches are a pure function of (seed, step), so resuming from a
checkpointed step reproduces the exact stream (no iterator state files), and
each data shard draws a disjoint sub-stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    a: int = 7
    c: int = 3


def batch_at(cfg: DataConfig, step: int,
             shard: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
    """The (sharded) batch for a given step; pure function of its args."""
    assert cfg.global_batch % num_shards == 0
    local = cfg.global_batch // num_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))
    B, T, V = local, cfg.seq_len, cfg.vocab
    toks = np.empty((B, T + 1), np.int32)
    toks[:, 0] = rng.integers(0, V, size=B)
    noise = rng.random((B, T)) < cfg.noise
    rand = rng.integers(0, V, size=(B, T))
    for t in range(T):
        nxt = (cfg.a * toks[:, t] + cfg.c) % V
        toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
    return {"tokens": toks[:, :-1],
            "targets": toks[:, 1:].astype(np.int32)}


def iterate(cfg: DataConfig, start_step: int = 0,
            shard: int = 0, num_shards: int = 1,
            ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step, shard, num_shards)
        step += 1

"""Synthetic graph workloads, skew-matched to the paper's datasets (§5.2.1).

SNAP/IMDB are not available offline; the paper's performance story rests on
*value-distribution skew* (hubs make adhesion keys recur), so we generate:

  * ``erdos_renyi``     — balanced degrees (p2p-Gnutella04 analogue),
  * ``barabasi_albert`` — heavy-tailed degrees (wiki-Vote / ego-* analogue),
  * ``zipf_graph``      — one edge table, Zipf-distributed endpoint
    popularity (hot vertices make adhesion keys recur — the conformance
    zoo's and the kernel benchmarks' shared skew source),
  * ``zipf_bipartite``  — two-table person/movie workload with separately
    tunable per-attribute skew (IMDB cast_info analogue, Fig 13/14).

Node ids stay < 2^21 so adhesion keys pack into int64 (cached_frontier).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..core.db import Database, graph_db


def erdos_renyi(n: int, m_edges: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(int(m_edges * 1.3), 2))
    e = e[e[:, 0] != e[:, 1]][:m_edges]
    return e.astype(np.int64)


def barabasi_albert(n: int, m_per_node: int = 3, seed: int = 0) -> np.ndarray:
    """Preferential attachment — heavy-tailed degree distribution."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_per_node))
    repeated: list = list(range(m_per_node))
    edges = []
    for v in range(m_per_node, n):
        chosen = rng.choice(repeated, size=m_per_node, replace=False) \
            if len(set(repeated)) >= m_per_node else \
            rng.integers(0, v, size=m_per_node)
        for u in set(int(u) for u in chosen):
            edges.append((v, u))
            repeated.extend([v, u])
    return np.asarray(edges, np.int64)


def zipf_graph(nv: int, ne: int, a: float, seed: int = 0) -> np.ndarray:
    """Edges with Zipf(``a``)-distributed endpoint popularity."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, nv + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    return np.stack([rng.choice(nv, size=ne, p=p),
                     rng.choice(nv, size=ne, p=p)], axis=1).astype(np.int64)


def zipf_bipartite(n_left: int, n_right: int, m: int, a_left: float,
                   a_right: float, seed: int = 0) -> np.ndarray:
    """Bipartite edges with Zipf-distributed endpoint popularity."""
    rng = np.random.default_rng(seed)

    def zipf_ids(n, a, size):
        ranks = np.arange(1, n + 1, dtype=np.float64)
        p = ranks ** (-a)
        p /= p.sum()
        return rng.choice(n, size=size, p=p)

    left = zipf_ids(n_left, a_left, m)
    right = zipf_ids(n_right, a_right, m)
    return np.stack([left, right], axis=1).astype(np.int64)


# ---------------------------------------------------------------------------
# Named datasets standing in for the paper's workloads
# ---------------------------------------------------------------------------

def dataset(name: str) -> Database:
    if name == "wiki-vote-like":        # small, skewed
        return graph_db(barabasi_albert(1200, 6, seed=1), symmetrize=False)
    if name == "gnutella-like":         # small, balanced
        return graph_db(erdos_renyi(2500, 7000, seed=2))
    if name == "ca-grqc-like":          # collaboration: symmetric, skewed
        return graph_db(barabasi_albert(1500, 4, seed=3), symmetrize=True)
    if name == "ego-facebook-like":     # denser, skewed
        return graph_db(barabasi_albert(800, 10, seed=4), symmetrize=True)
    if name == "ego-twitter-like":      # large, very skewed
        return graph_db(barabasi_albert(2000, 8, seed=5))
    if name == "imdb-like":             # two relations, per-attr skew
        male = zipf_bipartite(4000, 2500, 12000, 1.2, 0.6, seed=6)
        female = zipf_bipartite(4000, 2500, 12000, 1.2, 0.6, seed=7)
        return Database({"male_cast": male, "female_cast": female})
    raise KeyError(name)


DATASETS = ("wiki-vote-like", "gnutella-like", "ca-grqc-like",
            "ego-facebook-like", "ego-twitter-like", "imdb-like")

"""Training launcher: --arch <id> [--smoke] with mesh + FT loop.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --batch 8 --seq 64

Full-scale runs use the same entry point on a real TPU fleet; the mesh
shape, FSDP rules and checkpoint cadence come from flags.
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_arch
from ..data.tokens import DataConfig
from ..models import Model
from ..optim.adamw import OptConfig
from ..train.loop import LoopConfig, train
from ..train.train_step import TrainConfig
from .mesh import make_local_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = get_arch(name)
    model = Model(cfg)
    print(f"[train] {cfg.name}: {model.param_count()/1e6:.1f}M params, "
          f"{len(jax.devices())} devices")
    mesh = make_local_mesh(args.model_parallel) \
        if len(jax.devices()) > 1 else None
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    hist = train(
        model, data,
        TrainConfig(microbatches=args.microbatches,
                    opt=OptConfig(lr=args.lr, warmup_steps=10,
                                  decay_steps=args.steps)),
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                   log_every=10, ckpt_dir=args.ckpt_dir),
        mesh=mesh)
    print(f"[train] done: loss {hist['loss'][0]:.3f} -> "
          f"{hist['loss'][-1]:.3f}")


if __name__ == "__main__":
    main()

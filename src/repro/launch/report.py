"""Render EXPERIMENTS.md tables from dryrun_results.json.

    PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def _gib(b) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(rows: List[Dict], mesh: str) -> str:
    out = ["| arch | shape | status | args GiB/dev | temp GiB/dev | "
           "compile s | dominant collective |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} "
                       f"| — | — | — | {r.get('reason', '')[:40]} |")
            continue
        coll = r["roofline"]["collectives"]
        dom_c = max(coll, key=coll.get) if any(coll.values()) else "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {_gib(r['memory']['argument_bytes'])} "
            f"| {_gib(r['memory']['temp_bytes'])} "
            f"| {r['compile_s']} | {dom_c} |")
    return "\n".join(out)


def roofline_table(rows: List[Dict], mesh: str = "single") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | **{rf['dominant']}** "
            f"| {rf['useful_flop_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} |")
    return "\n".join(out)


def summary(rows: List[Dict]) -> str:
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skipped")
    er = sum(1 for r in rows if r["status"] == "error")
    return (f"{len(rows)} cells: {ok} compiled ok, {sk} skipped "
            f"(long_500k on full-attention archs), {er} errors")


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    rows = json.load(open(path))
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("## Summary\n")
    print(summary(rows))
    print("\n## Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table(rows, "single"))
    print("\n## Dry-run (multi-pod 2x16x16 = 512 chips)\n")
    print(dryrun_table(rows, "multi"))
    print("\n## Roofline (single-pod, scan-corrected)\n")
    print(roofline_table(rows, "single"))


if __name__ == "__main__":
    main()

"""Scan-corrected cost extraction (two-point unrolled probe).

``compiled.cost_analysis()`` counts a while-loop body ONCE, regardless of
trip count (verified empirically — see EXPERIMENTS.md §Perf, iteration 0),
so any scanned-layers model under-reports FLOPs/bytes/collectives by ~the
layer count.  The probe lowers two *fully unrolled* reduced-depth variants
of the same cell (depth = pattern+rem and 2·pattern+rem, every inner scan
unrolled via ``cfg.cost_exact``) at the same mesh/shardings, then
extrapolates linearly in the group count:

    C(full) = C(base) + (n_groups - 1) · (C(base+1group) - C(base))

which is exact for homogeneous group stacks (and for whisper, whose encoder
layer count equals its decoder group count, the encoder scales alongside).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from ..configs.base import ArchConfig
from . import roofline as rl


def _probe_cfg(cfg: ArchConfig, groups: int) -> ArchConfig:
    p = len(cfg.pattern)
    nl = groups * p + cfg.n_rem_layers
    kw = dict(n_layers=nl, cost_exact=True)
    if cfg.encoder_decoder:
        assert cfg.n_encoder_layers == cfg.n_groups, \
            "enc-dec probe assumes encoder layers == decoder groups"
        kw["n_encoder_layers"] = groups
    return dataclasses.replace(cfg, **kw)


def _costs(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    per_op = rl.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            **{f"coll_{k}": float(v) for k, v in per_op.items()}}


def probe_costs(cfg: ArchConfig, case, mesh, build_lowered) -> Dict:
    """Returns extrapolated per-device totals for the full-depth cell.

    Attention-free archs (family == "ssm") at long sequence: every cost
    component is exactly linear in T (token mixing is chunk-local with a
    fixed chunk), so the probe runs at a reduced sequence and scales by
    T/T_probe — unrolling tens of thousands of chunk bodies would otherwise
    dominate compile time.
    """
    scale = 1.0
    if cfg.family == "ssm" and case.kind != "decode" and case.seq > 4096:
        import dataclasses as _dc
        scale = case.seq / 4096
        case = _dc.replace(case, seq=4096)
    c1 = _costs(build_lowered(_probe_cfg(cfg, 1), case, mesh,
                              microbatches=1).compile())
    c2 = _costs(build_lowered(_probe_cfg(cfg, 2), case, mesh,
                              microbatches=1).compile())
    n_groups = cfg.n_groups
    out = {}
    for k in c1:
        delta = c2[k] - c1[k]
        out[k] = (c1[k] + max(n_groups - 1, 0) * delta) * scale
    per_op = {k[len("coll_"):]: v for k, v in out.items()
              if k.startswith("coll_")}
    return {"flops": out["flops"], "bytes": out["bytes"],
            "collectives": per_op, "seq_scale": scale,
            "probe_points": {"one_group": c1, "two_groups": c2}}

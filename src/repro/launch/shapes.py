"""Assigned input-shape grid + abstract input specs for the dry-run.

Every (arch × shape) cell resolves to ShapeDtypeStruct stand-ins (no device
allocation).  ``decode_*``/``long_*`` lower ``serve_step`` (one token against
a seq_len cache); ``long_500k`` requires sub-quadratic attention and is
skipped for pure full-attention archs (recorded, per DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524288, 1),
}

# archs with sub-quadratic sequence handling (hybrid local-attn / SSM)
SUBQUADRATIC = ("recurrentgemma-2b", "rwkv6-7b")


def cell_supported(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, ("full O(L^2) attention at 524288 would be a " +
                       "degenerate lowering; skipped per assignment")
    return True, ""


def batch_specs(cfg: ArchConfig, case: ShapeCase) -> Dict:
    """Token/modality inputs (ShapeDtypeStructs) for the cell."""
    B, T = case.batch, case.seq
    if case.kind == "decode":
        toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    else:
        toks = jax.ShapeDtypeStruct((B, T), jnp.int32)
    batch = {"tokens": toks}
    if case.kind == "train":
        batch["targets"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    if cfg.family == "vlm" and case.kind != "decode":
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio" and case.kind != "decode":
        batch["audio_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()
# ^ must precede every other import: jax locks the device count on first init.
"""Dry-run for the paper's technique itself: lower the distributed CLFTJ
(shard_map over candidate runs, private caches, one psum) on the production
meshes and report its roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun_join --out dryrun_join.json
"""
import argparse
import json
import time

import numpy as np
import jax

from ..core import CacheConfig, choose_plan, cycle_query, path_query
from ..core.db import graph_db
from ..core.distributed import make_distributed_count
from ..data.graphs import barabasi_albert
from . import roofline as rl
from .mesh import make_production_mesh


def lower_join(multi_pod: bool, capacity: int = 1 << 14,
               cache_slots: int = 1 << 15, query: str = "5-cycle"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    db = graph_db(barabasi_albert(4000, 8, seed=11))
    q = cycle_query(5) if query == "5-cycle" else path_query(5)
    td, order = choose_plan(q, db.stats())
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    fn, eng = make_distributed_count(
        q, td, order, db, mesh, capacity=capacity,
        cache=CacheConfig(policy="direct", slots=cache_slots), axes=axes)
    with mesh:
        t0 = time.time()
        lowered = fn.lower()
        compiled = lowered.compile()
        dt = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        per_op = rl.collective_bytes(compiled.as_text())
    return {
        "kind": "join_engine", "query": query,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": mesh.size, "capacity": capacity,
        "cache_slots": cache_slots, "compile_s": round(dt, 1),
        "status": "ok",
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        },
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": per_op,
        "collective_bytes_weighted":
            rl.weighted_collective_bytes(per_op),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="dryrun_join.json")
    args = ap.parse_args()
    recs = []
    for mp in (False, True):
        for query in ("5-cycle", "5-path"):
            print(f"[dryrun-join] multi_pod={mp} {query} ...", flush=True)
            rec = lower_join(mp, query=query)
            recs.append(rec)
            print(f"  ok: compile {rec['compile_s']}s  "
                  f"coll={rec['collective_bytes_weighted']/1e3:.1f} KB  "
                  f"temp={rec['memory']['temp_bytes']/2**20:.0f} MiB",
                  flush=True)
            with open(args.out, "w") as f:
                json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()
# ^ must precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: AOT-lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real train_step / prefill / decode_step under
the production mesh with the production shardings, compiles it, and records
memory_analysis / cost_analysis / collective mix — proving the distribution
config is coherent without hardware.  Results append incrementally to a JSON
file consumed by EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
"""
import argparse
import functools
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import tree_map_with_path
from ..configs import ARCHS, get_arch
from ..models import Model
from ..optim import adamw
from ..sharding import rules as shr
from ..train.train_step import TrainConfig, make_train_step
from . import roofline as rl
from .mesh import make_production_mesh
from .shapes import SHAPES, ShapeCase, batch_specs, cell_supported


# ---------------------------------------------------------------------------
# Sharding of abstract inputs
# ---------------------------------------------------------------------------

def _is_logical(x) -> bool:
    return isinstance(x, tuple) and (len(x) == 0 or
                                     isinstance(x[0], (str, type(None))))


def param_shardings(model: Model, mesh, rules=None):
    return jax.tree.map(
        lambda lg, sh: shr.named_sharding(mesh, lg, sh.shape, rules),
        model.logical_axes(), model.param_shapes(), is_leaf=_is_logical)


def state_struct(model: Model):
    shapes = model.param_shapes()
    return {"params": shapes,
            "opt": {"m": shapes, "v": shapes,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def state_shardings(model: Model, mesh, rules=None, opt_rules=None):
    """params under ``rules``; optimizer moments optionally under different
    rules (ZeRO-1: params TP-replicated for compute, moments fully sharded)."""
    p = param_shardings(model, mesh, rules)
    o = param_shardings(model, mesh, opt_rules) if opt_rules is not None \
        else p
    return {"params": p,
            "opt": {"m": o, "v": o, "step": NamedSharding(mesh, P())}}


def serve_param_struct(model: Model):
    """Serving params are bf16 (weight-only cast, standard deployment)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
        model.param_shapes())


def serve_rules(model: Model, mesh):
    """TP serving; weight-gathered (ZeRO-inference) only when bf16 weights
    exceed the per-device HBM budget under pure TP (e.g. qwen3-235b)."""
    tp = mesh.shape.get("model", 1)
    bytes_tp = model.param_count() * 2 / tp
    if bytes_tp > 12 * 2 ** 30:
        return shr.FSDP_RULES
    return None


def batch_shardings(batch_struct: Dict, mesh):
    out = {}
    for k, v in batch_struct.items():
        b = v.shape[0]
        lead = shr.batch_sharding(mesh, b)
        spec = lead.spec
        out[k] = NamedSharding(mesh, P(*(list(spec) + [None] *
                                         (len(v.shape) - len(spec)))))
    return out


_CACHE_LOGICAL = {
    # leaf name -> logical axes, rightmost dims (leading dims -> None).
    # Dense caches shard their depth (kv_seq) over 'model': every assigned
    # arch has kv_heads <= 8, which never divides a 16-way model axis.
    "k": ("batch", "kv_seq", None, None),
    "v": ("batch", "kv_seq", None, None),
    "xk": ("batch", "kv_seq", None, None),
    "xv": ("batch", "kv_seq", None, None),
    "kpos": (None,),
    "h": ("batch", "rnn"),
    "conv": ("batch", None, "rnn"),
    "s": ("batch", "heads", None, None),
    "shift_t": ("batch", None),
    "shift_c": ("batch", None),
}


def cache_shardings(cache_struct, mesh):
    def leaf(path, s):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        logical = _CACHE_LOGICAL[name]
        full = (None,) * (len(s.shape) - len(logical)) + logical
        # batch axis respects divisibility (B=1 long_500k -> replicated)
        spec = []
        for dim, lg in zip(s.shape, full):
            if lg == "batch":
                spec.append(shr.batch_sharding(mesh, dim).spec[0]
                            if shr.batch_sharding(mesh, dim).spec else None)
            elif lg is None:
                spec.append(None)
            else:
                ps = shr.partition_spec((lg,), (dim,), mesh)
                spec.append(ps[0])
        return NamedSharding(mesh, P(*spec))

    return tree_map_with_path(leaf, cache_struct)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def build_lowered(cfg, case, mesh, microbatches: int = 4,
                  grad_dtype: str = "float32", fsdp="zero3",
                  srules_override=None):
    """Lower the cell's step function under the mesh with full shardings.

    ``fsdp``: "zero3" (params+moments fully sharded; per-layer gathers),
    "zero1" (params TP-only for compute, moments fully sharded), or
    "tp"/False (pure tensor parallelism).  True maps to "zero3".
    """
    model = Model(cfg)
    bspec = batch_specs(cfg, case)
    bshard = batch_shardings(bspec, mesh)
    if fsdp is True:
        fsdp = "zero3"
    if fsdp is False:
        fsdp = "tp"
    with mesh:
        if case.kind == "train":
            mb = microbatches if case.batch % microbatches == 0 else 1
            tc = TrainConfig(microbatches=mb, grad_dtype=grad_dtype)
            step = make_train_step(model, tc, mesh)
            if fsdp == "zero3":
                sshard = state_shardings(model, mesh, shr.FSDP_RULES)
            elif fsdp == "zero3_outdim":
                sshard = state_shardings(model, mesh, shr.MOE_FSDP_OUTDIM)
            elif fsdp == "zero1":
                sshard = state_shardings(model, mesh, None,
                                         opt_rules=shr.FSDP_RULES)
            else:
                sshard = state_shardings(model, mesh)
            return jax.jit(
                step,
                in_shardings=(sshard, bshard),
            ).lower(state_struct(model), bspec)
        srules = srules_override if srules_override is not None \
            else serve_rules(model, mesh)
        pstruct = serve_param_struct(model)
        pshard = param_shardings(model, mesh, srules)
        if case.kind == "prefill":
            return jax.jit(
                model.prefill,
                in_shardings=(pshard, bshard),
            ).lower(pstruct, bspec)
        # decode
        cstruct = model.cache_shapes(case.batch, case.seq)
        cshard = cache_shardings(cstruct, mesh)
        tokens = jax.ShapeDtypeStruct((case.batch, 1), jnp.int32)
        tshard = batch_shardings({"tokens": tokens}, mesh)["tokens"]
        return jax.jit(
            model.decode,
            in_shardings=(pshard, cshard, tshard, NamedSharding(mesh, P())),
        ).lower(pstruct, cstruct, tokens,
                jax.ShapeDtypeStruct((), jnp.int32))


def lower_cell(arch: str, shape: str, multi_pod: bool,
               remat: Optional[str] = None, probe: bool = True,
               microbatches: int = 4) -> Dict:
    cfg = get_arch(arch)
    if remat:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    case = SHAPES[shape]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    # decide serving rules on the FULL config once, so the reduced-depth
    # probes lower under the same sharding strategy as the main cell
    srules = serve_rules(Model(cfg), mesh) or dict(shr.DEFAULT_RULES)
    build = functools.partial(build_lowered, srules_override=srules)
    lowered = build(cfg, case, mesh, microbatches=microbatches)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    raw = rl.analyze(compiled, cfg, case, n_dev)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "roofline_raw": raw.as_dict(),
    }
    if probe:
        # scan-corrected totals (see costprobe.py): this is the §Roofline row
        from .costprobe import probe_costs
        pc = probe_costs(cfg, case, mesh, build)
        corr = rl.Roofline(
            flops=pc["flops"], bytes_accessed=pc["bytes"],
            coll_bytes=rl.weighted_collective_bytes(pc["collectives"]),
            per_op={k: int(v) for k, v in pc["collectives"].items()},
            n_devices=n_dev,
            model_flops_per_device=rl.model_flops(cfg, case, n_dev))
        rec["roofline"] = corr.as_dict()
        rec["probe_points"] = pc["probe_points"]
    else:
        rec["roofline"] = rec["roofline_raw"]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--remat", default=None)
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--no-probe", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "multi" if mp else "single")
                if args.skip_done and key in done:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp, remat=args.remat,
                                     probe=not args.no_probe,
                                     microbatches=args.microbatches)
                except Exception as e:   # a failure here is a bug: record it
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": str(e)[:2000],
                           "trace": traceback.format_exc()[-2000:]}
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if rec["status"] == "ok":
                    m = rec["memory"]
                    r = rec["roofline"]
                    print(f"  ok: compile {rec['compile_s']}s  "
                          f"args {m['argument_bytes']/2**30:.2f} GiB/dev  "
                          f"temp {m['temp_bytes']/2**30:.2f} GiB/dev  "
                          f"dominant={r['dominant']}  "
                          f"roofline_frac={r['roofline_fraction']:.3f}",
                          flush=True)
                else:
                    print(f"  {rec['status']}: "
                          f"{rec.get('reason', rec.get('error', ''))[:200]}",
                          flush=True)


if __name__ == "__main__":
    main()

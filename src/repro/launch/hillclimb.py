import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()
# ^ must precede every other import: jax locks the device count on first init.
"""Perf hillclimbing over dry-run cells: lower named variants of a cell and
report roofline-term deltas (hypothesis -> change -> before/after is logged
into EXPERIMENTS.md §Perf from this output).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell minitron-8b:train_4k
"""
import argparse
import dataclasses
import functools
import json

from ..configs import get_arch
from ..models import Model
from . import roofline as rl
from .costprobe import probe_costs
from .dryrun import build_lowered
from .mesh import make_production_mesh
from .shapes import SHAPES


def measure(cfg, case, mesh, microbatches=8, grad_dtype="float32",
            fsdp="zero3", srules=None):
    from ..sharding import rules as shr
    srules_override = None
    if srules == "fsdp":
        srules_override = shr.FSDP_RULES
    elif srules == "moe":
        srules_override = shr.MOE_SERVE_RULES
    elif srules == "tp":
        srules_override = dict(shr.DEFAULT_RULES)
    build = functools.partial(build_lowered, grad_dtype=grad_dtype,
                              fsdp=fsdp, srules_override=srules_override)
    compiled = build(cfg, case, mesh, microbatches=microbatches).compile()
    mem = compiled.memory_analysis()
    pc = probe_costs(cfg, case, mesh,
                     lambda c, cs, m, microbatches=1: build(
                         c, cs, m, microbatches=microbatches))
    roof = rl.Roofline(
        flops=pc["flops"], bytes_accessed=pc["bytes"],
        coll_bytes=rl.weighted_collective_bytes(pc["collectives"]),
        per_op={k: int(v) for k, v in pc["collectives"].items()},
        n_devices=mesh.size,
        model_flops_per_device=rl.model_flops(cfg, case, mesh.size))
    return {
        "temp_gib": mem.temp_size_in_bytes / 2 ** 30,
        "arg_gib": mem.argument_size_in_bytes / 2 ** 30,
        **roof.as_dict(),
    }


VARIANTS = {
    "train": [
        ("baseline(mb8,zero3,remat=full)", {}),
        ("tp_only", {"fsdp": "tp"}),
        ("zero1", {"fsdp": "zero1"}),
        ("zero1+seq_shard", {"fsdp": "zero1",
                             "cfg": {"seq_shard": True}}),
        ("zero1+seq_shard+grad_bf16",
         {"fsdp": "zero1", "cfg": {"seq_shard": True},
          "grad_dtype": "bfloat16"}),
        ("seq_shard(zero3)", {"cfg": {"seq_shard": True}}),
        ("mb16", {"microbatches": 16}),
        ("remat_dots", {"cfg": {"remat_policy": "dots"}}),
    ],
    "moe": [
        ("baseline(mb8,fsdp)", {}),
        ("seq_shard", {"cfg": {"seq_shard": True}}),
        ("mb16", {"microbatches": 16}),
        ("capacity1.0", {"cfg": {"capacity_factor": 1.0}}),
        ("zero3_outdim(mlp over data)", {"fsdp": "zero3_outdim"}),
        ("zero3_outdim+seq_shard", {"fsdp": "zero3_outdim",
                                    "cfg": {"seq_shard": True}}),
        ("seq_shard+cap1.0+bf16",
         {"cfg": {"seq_shard": True, "capacity_factor": 1.0},
          "grad_dtype": "bfloat16"}),
    ],
    "serve": [
        ("baseline(auto rules)", {}),
        ("zero_inference(weight-gather)", {"srules": "fsdp"}),
        ("expert_data(a2a tokens)", {"srules": "moe"}),
        ("tp_only", {"srules": "tp"}),
    ],
}

_SRULES = {"fsdp": "FSDP_RULES", "moe": "MOE_SERVE_RULES", "tp": "DEFAULT"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="<arch>:<shape>, e.g. minitron-8b:train_4k")
    ap.add_argument("--set", default="train", choices=list(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    base_cfg = get_arch(arch)
    case = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    results = []
    for name, spec in VARIANTS[args.set]:
        if spec is None:
            continue
        cfg = dataclasses.replace(base_cfg, **spec.get("cfg", {}))
        kw = {k: v for k, v in spec.items() if k != "cfg"}
        print(f"[hillclimb] {args.cell} :: {name} ...", flush=True)
        try:
            m = measure(cfg, case, mesh, **kw)
        except Exception as e:
            print(f"  error: {e}")
            results.append({"variant": name, "error": str(e)[:500]})
            continue
        results.append({"variant": name, **m})
        print(f"  compute {m['compute_s']:.4f}s  memory {m['memory_s']:.4f}s"
              f"  coll {m['collective_s']:.4f}s  temp {m['temp_gib']:.1f}GiB"
              f"  dom={m['dominant']}  frac={m['roofline_fraction']:.3f}",
              flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()

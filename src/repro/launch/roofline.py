"""Roofline-term extraction from AOT-compiled artifacts (no hardware).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

* compute term    = HLO_FLOPs / peak            (cost_analysis FLOPs; the
                    compiled module is the per-device SPMD program, so terms
                    are seconds-per-step-per-device)
* memory term     = HLO_bytes / HBM_bw          (cost_analysis bytes accessed)
* collective term = Σ collective bytes / ICI_bw (parsed from optimized HLO;
                    shapes in the partitioned module are per-device shards;
                    ring all-reduce weighted 2x for its two passes)

MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = routed-active params —
the ratio MODEL_FLOPS/HLO_FLOPs exposes remat and padding waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_DONE_RE = re.compile(r"-(done|update)\(")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective byte totals (result-shape bytes, per device)."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if _DONE_RE.search(line):
            continue  # async -done/-update: already counted at -start
        m = _OP_RE.search(line)
        if not m:
            continue
        typestr, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(typestr)
    return out


def weighted_collective_bytes(per_op: Dict[str, int]) -> float:
    w = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}
    return sum(per_op[k] * w[k] for k in per_op)


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    per_op: Dict[str, int]
    n_devices: int
    model_flops_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        return (self.model_flops_per_device / self.flops) if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step ran at its
        dominant-term speed: (useful FLOPs / peak) / bound time."""
        if self.bound_s == 0:
            return 0.0
        return (self.model_flops_per_device / PEAK_FLOPS) / self.bound_s

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.coll_bytes,
            "collectives": self.per_op,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_per_device": self.model_flops_per_device,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, case, n_devices: int) -> float:
    """6·N·tokens (train) or 2·N·tokens (inference), per device."""
    n_active = cfg.active_param_count()
    if case.kind == "train":
        tokens = case.batch * case.seq
        total = 6.0 * n_active * tokens
    elif case.kind == "prefill":
        tokens = case.batch * case.seq
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * case.batch
    return total / n_devices


def analyze(compiled, cfg, case, n_devices: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    per_op = collective_bytes(compiled.as_text())
    return Roofline(flops=flops, bytes_accessed=nbytes,
                    coll_bytes=weighted_collective_bytes(per_op),
                    per_op=per_op, n_devices=n_devices,
                    model_flops_per_device=model_flops(cfg, case, n_devices))

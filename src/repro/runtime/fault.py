"""Fault-tolerance runtime: preemption handling, straggler watch, retries.

On a real fleet this wraps the per-host training process: SIGTERM (the
standard preemption notice) triggers a final synchronous checkpoint; a
watchdog thread flags steps that exceed a multiple of the trailing median
step time (straggling host / hung collective) so the launcher can restart
the slow worker; ``retry`` wraps transient-failure-prone calls.
"""
from __future__ import annotations

import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers that request a graceful stop."""

    def __init__(self):
        self._stop = threading.Event()
        self._orig = {}

    def install(self) -> "PreemptionGuard":
        for sig in (signal.SIGTERM,):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:      # non-main thread (tests)
                pass
        return self

    def _handler(self, signum, frame):
        self._stop.set()

    def request_stop(self) -> None:
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()


@dataclass
class StragglerWatch:
    """Flags steps slower than ``factor`` x trailing-median step time."""

    factor: float = 3.0
    window: int = 32
    history: List[float] = field(default_factory=list)
    flagged: int = 0
    on_flag: Optional[Callable[[float, float], None]] = None

    def observe(self, step_seconds: float) -> bool:
        hist = self.history[-self.window:]
        slow = False
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            if step_seconds > self.factor * med:
                self.flagged += 1
                slow = True
                if self.on_flag:
                    self.on_flag(step_seconds, med)
        self.history.append(step_seconds)
        return slow


def retry(fn: Callable, attempts: int = 3, backoff_s: float = 0.5,
          exceptions=(RuntimeError, OSError)):
    """Retry transient failures with exponential backoff."""
    last = None
    for i in range(attempts):
        try:
            return fn()
        except exceptions as e:      # pragma: no cover - timing dependent
            last = e
            time.sleep(backoff_s * (2 ** i))
    raise last

"""Elastic re-scaling: restore a checkpoint under a different mesh.

Checkpoints store logical (unsharded) arrays, so scaling from N to M chips
is just `restore(..., shardings=tree_shardings(new_mesh, ...))` — every leaf
is re-placed under the new mesh's partitioning.  The data pipeline is a pure
function of (seed, step, shard) so it re-shards for free.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from ..checkpoint.ckpt import CheckpointManager
from ..models import Model
from ..sharding import rules as shr
from ..train.train_step import state_shardings


def restore_for_mesh(ckpt: CheckpointManager, model: Model, mesh,
                     step: Optional[int] = None):
    """Restore the train state resharded for ``mesh`` (any device count)."""
    import jax.numpy as jnp
    from ..optim import adamw

    shapes = model.param_shapes()
    like = {"params": shapes,
            "opt": {"m": shapes, "v": shapes,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}
    shardings = state_shardings(model, mesh) if mesh is not None else None
    return ckpt.restore(like, step=step, shardings=shardings)

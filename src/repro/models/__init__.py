"""LM substrate: model zoo for the assigned architectures."""
from .model import Model

"""RWKV-6 ("Finch") block: data-dependent-decay linear attention, chunked.

TPU adaptation (DESIGN.md §2): the per-token recurrence

    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t            (per head, (Dk, Dv) state)
    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

is evaluated chunk-wise: within a chunk of length L the interaction matrix
A[t,s] = Σ_d r_{t,d} k_{s,d} exp(lp_{t-1,d} − lp_{s,d}) (lp = cumulative log
decay) factors into two MXU einsums (r·e^{lp} against k·e^{−lp}), the carry
state enters through one more einsum, and the cross-chunk state update is a
third — all dense matmuls instead of a length-T scan.  The per-step log decay
is clipped to ≥ −0.5·e so e^{±lp} stays within fp32 over a 32-step chunk
(recorded as a modelling restriction in DESIGN.md).

Simplifications vs the reference implementation (noted in DESIGN.md): static
token-shift mixing coefficients (no LoRA on μ), per-channel decay projected
by a single dense matrix, RMS-style per-channel output norm instead of
GroupNorm.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import cdt

CHUNK = 32
_W_CLIP = 0.5  # clip on exp-arg: per-step log-decay >= -e^0.5 ≈ -1.65


def _shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Token shift: x_{t-1}, with ``prev`` = last token of previous segment."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _heads(x: jnp.ndarray, dh: int) -> jnp.ndarray:
    b, t, d = x.shape
    return x.reshape(b, t, d // dh, dh).transpose(0, 2, 1, 3)  # (B,H,T,dh)


def rwkv_time_mix(cfg: ArchConfig, p: Dict, x: jnp.ndarray, *,
                  state: Optional[jnp.ndarray] = None,
                  shift_prev: Optional[jnp.ndarray] = None,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: normed (B, T, D).  Returns (out, new_state, new_shift)."""
    dt = cdt(cfg)
    B, T, D = x.shape
    dh = cfg.rwkv_head_dim
    H = D // dh
    xs = _shift(x, shift_prev)
    r = _heads(_mix(x, xs, p["mu_r"]) @ p["wr"].astype(dt), dh)
    k = _heads(_mix(x, xs, p["mu_k"]) @ p["wk"].astype(dt), dh)
    v = _heads(_mix(x, xs, p["mu_v"]) @ p["wv"].astype(dt), dh)
    g = jax.nn.silu(_mix(x, xs, p["mu_g"]) @ p["wg"].astype(dt))
    w_arg = (_mix(x, xs, p["mu_w"]).astype(jnp.float32)
             @ p["ww"].astype(jnp.float32)) + p["w_bias"]
    logw = -jnp.exp(jnp.clip(w_arg, -8.0, _W_CLIP))          # (B,T,D) <= 0
    logw = _heads(logw, dh)                                   # (B,H,T,dh)
    u = p["u"].reshape(H, dh).astype(jnp.float32)

    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    if state is None:
        state = jnp.zeros((B, H, dh, dh), jnp.float32)

    L = min(CHUNK, T)
    nC = -(-T // L)
    pad = nC * L - T
    if pad:
        r, k, v, logw = (jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
                         for a in (r, k, v, logw))

    def chunk_step(S, inp):
        rc, kc, vc, lwc = inp                                 # (B,H,L,dh)
        lp = jnp.cumsum(lwc, axis=2)                          # inclusive
        lp_prev = lp - lwc                                    # exclusive
        q_ = rc * jnp.exp(lp_prev)
        k_ = kc * jnp.exp(-lp)
        A = jnp.einsum("bhtd,bhsd->bhts", q_, k_)
        tri = jnp.tril(jnp.ones((L, L), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        diag = jnp.einsum("bhtd,bhtd,hd->bht", rc, kc, u)
        y = jnp.einsum("bhts,bhse->bhte", A, vc)
        y = y + jnp.einsum("bhtd,bhde->bhte", q_, S)          # carry term
        y = y + diag[..., None] * vc
        lpL = lp[:, :, -1:, :]                                # (B,H,1,dh)
        kd = kc * jnp.exp(lpL - lp)
        S_new = jnp.exp(lpL[:, :, 0, :, None]) * S + \
            jnp.einsum("bhsd,bhse->bhde", kd, vc)
        return S_new, y

    rs = r.reshape(B, H, nC, L, dh).transpose(2, 0, 1, 3, 4)
    ks = k.reshape(B, H, nC, L, dh).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, H, nC, L, dh).transpose(2, 0, 1, 3, 4)
    ws = logw.reshape(B, H, nC, L, dh).transpose(2, 0, 1, 3, 4)
    if cfg.cost_exact:     # cost-probe mode: unroll the chunk loop
        S_fin, ys_l = state, []
        for ci in range(nC):
            S_fin, yc = chunk_step(S_fin, (rs[ci], ks[ci], vs[ci], ws[ci]))
            ys_l.append(yc)
        ys = jnp.stack(ys_l)
    else:
        S_fin, ys = jax.lax.scan(chunk_step, state, (rs, ks, vs, ws))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, nC * L, dh)[:, :, :T]
    y = y.transpose(0, 2, 1, 3).reshape(B, T, D)
    # per-channel output norm (GroupNorm stand-in) + gate
    y = y * jax.lax.rsqrt((y ** 2).mean(-1, keepdims=True) + 1e-6)
    y = (y * p["gn_scale"]).astype(dt) * g
    out = y @ p["wo"].astype(dt)
    return out, S_fin, x[:, -1].astype(jnp.float32)


def rwkv_channel_mix(cfg: ArchConfig, p: Dict, x: jnp.ndarray, *,
                     shift_prev: Optional[jnp.ndarray] = None,
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    dt = cdt(cfg)
    xs = _shift(x, shift_prev)
    k = _mix(x, xs, p["c_mu_k"]) @ p["c_wk"].astype(dt)
    k = jnp.square(jax.nn.relu(k))
    rgate = jax.nn.sigmoid(_mix(x, xs, p["c_mu_r"]) @ p["c_wr"].astype(dt))
    return (k @ p["c_wv"].astype(dt)) * rgate, x[:, -1].astype(jnp.float32)


def rwkv_time_mix_step(cfg: ArchConfig, p: Dict, x: jnp.ndarray, *,
                       state: jnp.ndarray, shift_prev: jnp.ndarray,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token recurrence (decode).  x: (B, 1, D)."""
    dt = cdt(cfg)
    B, _, D = x.shape
    dh = cfg.rwkv_head_dim
    H = D // dh
    xs = shift_prev[:, None].astype(x.dtype)
    r = (_mix(x, xs, p["mu_r"]) @ p["wr"].astype(dt))[:, 0] \
        .reshape(B, H, dh).astype(jnp.float32)
    k = (_mix(x, xs, p["mu_k"]) @ p["wk"].astype(dt))[:, 0] \
        .reshape(B, H, dh).astype(jnp.float32)
    v = (_mix(x, xs, p["mu_v"]) @ p["wv"].astype(dt))[:, 0] \
        .reshape(B, H, dh).astype(jnp.float32)
    g = jax.nn.silu(_mix(x, xs, p["mu_g"]) @ p["wg"].astype(dt))[:, 0]
    w_arg = ((_mix(x, xs, p["mu_w"]).astype(jnp.float32)
              @ p["ww"].astype(jnp.float32)) + p["w_bias"])[:, 0]
    w = jnp.exp(-jnp.exp(jnp.clip(w_arg, -8.0, _W_CLIP))).reshape(B, H, dh)
    u = p["u"].reshape(H, dh).astype(jnp.float32)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    y = jnp.einsum("bhd,bhde->bhe", r, state + u[None, :, :, None] * kv)
    state = w[..., None] * state + kv
    y = y.reshape(B, 1, D)
    y = y * jax.lax.rsqrt((y ** 2).mean(-1, keepdims=True) + 1e-6)
    y = (y * p["gn_scale"]).astype(dt) * g[:, None]
    out = y @ p["wo"].astype(dt)
    return out, state, x[:, -1].astype(jnp.float32)

"""RG-LRU recurrent block (RecurrentGemma / Griffin) — TPU-native form.

The diagonal gated linear recurrence

    a_t = exp(-c · softplus(Λ) · σ(W_a x_t)),   c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (σ(W_i x_t) ⊙ x_t)

is evaluated with ``jax.lax.associative_scan`` over time (log-depth on TPU
instead of a length-T sequential loop).  A short causal conv1d precedes the
recurrence as in Griffin's recurrent block; decode carries (h, conv tail).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import cdt

_C = 8.0


def _conv1d(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
            state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Causal depthwise conv over time.  x: (B, T, R)."""
    cw = cfg.conv_width
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
              for i in range(cw))
    out = out + p["conv_b"].astype(x.dtype)
    return out, xp[:, -(cw - 1):]  # new conv tail


def _gates(p: Dict, xc: jnp.ndarray):
    f32 = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(f32 @ p["wa"].astype(jnp.float32))
    i = jax.nn.sigmoid(f32 @ p["wi"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) * (i * f32)
    return a, b


def rglru_scan(cfg: ArchConfig, p: Dict, xc: jnp.ndarray,
               h0: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence recurrence.  xc: (B, T, R) conv output.
    Returns (h over time (B,T,R) f32, final state (B,R))."""
    a, b = _gates(p, xc)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_step(cfg: ArchConfig, p: Dict, xc: jnp.ndarray,
               h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step.  xc: (B, 1, R)."""
    a, b = _gates(p, xc)
    h = a[:, 0] * h0.astype(jnp.float32) + b[:, 0]
    return h[:, None], h


def rglru_block(cfg: ArchConfig, p: Dict, x: jnp.ndarray, *,
                cache: Optional[Dict] = None,
                ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Griffin recurrent block: (gelu gate branch) ⊙ (conv → RG-LRU branch).

    x: normed input (B, T, D).  Returns (out (B,T,D), new cache or None).
    """
    dt = cdt(cfg)
    y = jax.nn.gelu(x @ p["wy"].astype(dt))
    xb = x @ p["wx"].astype(dt)
    conv_state = cache["conv"] if cache is not None else None
    h0 = cache["h"] if cache is not None else None
    xc, conv_tail = _conv1d(cfg, p, xb, conv_state)
    if cache is not None and x.shape[1] == 1:
        h, h_last = rglru_step(cfg, p, xc, h0)
    else:
        h, h_last = rglru_scan(cfg, p, xc, h0)
    out = (y * h.astype(dt)) @ p["wout"].astype(dt)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_last.astype(jnp.float32),
                     "conv": conv_tail.astype(cache["conv"].dtype)}
    return out, new_cache

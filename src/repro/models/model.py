"""Model facade: init / loss / prefill / decode, plus shape-only variants
for the dry-run (no allocation — everything derives from ParamSpecs)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import transformer as T
from . import specs as S
from .kvcache import cache_shapes, init_cache


@dataclass
class Model:
    cfg: ArchConfig
    impl: str = "xla"

    # -- parameters ---------------------------------------------------------
    def specs(self) -> Dict:
        return S.model_specs(self.cfg)

    def init(self, key) -> Dict:
        return S.init_params(self.specs(), key)

    def param_shapes(self) -> Dict:
        return S.spec_shapes(self.specs())

    def logical_axes(self) -> Dict:
        return S.logical_axes(self.specs())

    def param_count(self) -> int:
        return S.count_params(self.specs())

    # -- training -----------------------------------------------------------
    loss_chunk: int = 512

    def loss(self, params: Dict, batch: Dict,
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Cross-entropy with *chunked* logits: the (B, c, V) logits block is
        recomputed in the backward pass (jax.checkpoint), so the full
        (B, T, V) fp32 logits tensor never materializes — essential for the
        big-vocab / unshardable-vocab architectures (DESIGN.md §3)."""
        hidden, aux = T.forward_hidden(self.cfg, params, batch,
                                       impl=self.impl)
        targets = batch["targets"]
        mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
        B, T_, D = hidden.shape
        c = self.loss_chunk if T_ % self.loss_chunk == 0 else T_
        if self.cfg.cost_exact:
            c = T_                 # cost-probe: no loss-chunk scan
        n = T_ // c

        def chunk(carry, xs):
            h_c, t_c, m_c = xs                  # (B, c, D) (B, c) (B, c)
            logits = T.logits_fn(self.cfg, params, h_c)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(
                logp, t_c[..., None], axis=-1)[..., 0]
            s, m = carry
            return (s + (nll * m_c).sum(), m + m_c.sum()), None

        xs = (hidden.reshape(B, n, c, D).transpose(1, 0, 2, 3),
              targets.reshape(B, n, c).transpose(1, 0, 2),
              mask.reshape(B, n, c).transpose(1, 0, 2))
        (nll_sum, mask_sum), _ = jax.lax.scan(
            jax.checkpoint(chunk),
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), xs)
        denom = jnp.maximum(mask_sum, 1.0)
        ce = nll_sum / denom
        loss = ce + aux
        return loss, {"loss": loss, "ce": ce, "aux": aux, "tokens": mask_sum}

    # -- serving --------------------------------------------------------------
    def prefill(self, params: Dict, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        return T.prefill(self.cfg, params, batch, impl=self.impl)

    def decode(self, params: Dict, caches: Dict, tokens: jnp.ndarray,
               pos: jnp.ndarray, batch: Optional[Dict] = None,
               ) -> Tuple[jnp.ndarray, Dict]:
        return T.decode_step(self.cfg, params, caches, tokens, pos,
                             batch or {}, impl=self.impl)

    # -- serving shapes (dry-run) ---------------------------------------------
    def cache_shapes(self, batch: int, seq: int) -> Dict:
        return cache_shapes(self.cfg, batch, seq)

    def init_cache(self, batch: int, seq: int) -> Dict:
        return init_cache(self.cfg, batch, seq)

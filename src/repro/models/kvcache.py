"""Serving caches per block kind.

Dense causal blocks keep (B, S, Hkv, Dh) key/value buffers; sliding-window
blocks keep a W-slot ring (plus an absolute-position array so masking needs
no modular arithmetic at attend time); recurrent blocks keep O(1) state —
which is why the hybrid/SSM architectures are the only ones that run the
``long_500k`` shape (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


def block_cache_shapes(cfg: ArchConfig, kind: str, batch: int,
                       seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    Hkv, dh, D = cfg.n_kv_heads, cfg.dh, cfg.d_model
    bf = jnp.bfloat16
    if kind in ("attn", "moe"):
        return {"k": jax.ShapeDtypeStruct((batch, seq, Hkv, dh), bf),
                "v": jax.ShapeDtypeStruct((batch, seq, Hkv, dh), bf)}
    if kind == "local":
        w = cfg.window or seq        # ring always has `window` slots
        return {"k": jax.ShapeDtypeStruct((batch, w, Hkv, dh), bf),
                "v": jax.ShapeDtypeStruct((batch, w, Hkv, dh), bf),
                "kpos": jax.ShapeDtypeStruct((w,), jnp.int32)}
    if kind == "cross":
        return {"k": jax.ShapeDtypeStruct(
                    (batch, cfg.n_image_tokens, Hkv, dh), bf),
                "v": jax.ShapeDtypeStruct(
                    (batch, cfg.n_image_tokens, Hkv, dh), bf)}
    if kind == "rglru":
        R = cfg.d_rnn or D
        return {"h": jax.ShapeDtypeStruct((batch, R), jnp.float32),
                "conv": jax.ShapeDtypeStruct(
                    (batch, cfg.conv_width - 1, R), bf)}
    if kind == "rwkv":
        dh_r = cfg.rwkv_head_dim
        H = D // dh_r
        return {"s": jax.ShapeDtypeStruct((batch, H, dh_r, dh_r),
                                          jnp.float32),
                "shift_t": jax.ShapeDtypeStruct((batch, D), jnp.float32),
                "shift_c": jax.ShapeDtypeStruct((batch, D), jnp.float32)}
    if kind == "dec":
        enc = cfg.encoder_seq
        return {"k": jax.ShapeDtypeStruct((batch, seq, Hkv, dh), bf),
                "v": jax.ShapeDtypeStruct((batch, seq, Hkv, dh), bf),
                "xk": jax.ShapeDtypeStruct((batch, enc, cfg.n_heads, dh), bf),
                "xv": jax.ShapeDtypeStruct((batch, enc, cfg.n_heads, dh), bf)}
    raise ValueError(kind)


def _stackshape(tree, n: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def cache_shapes(cfg: ArchConfig, batch: int, seq: int) -> Dict:
    """Full cache pytree (ShapeDtypeStructs) mirroring the params layout."""
    out: Dict = {}
    pat = cfg.pattern
    if cfg.n_groups > 0:
        out["groups"] = {
            f"b{i}_{k}": _stackshape(
                block_cache_shapes(cfg, k, batch, seq), cfg.n_groups)
            for i, k in enumerate(pat)}
    if cfg.n_rem_layers:
        out["rem"] = {f"r{i}_{k}": block_cache_shapes(cfg, k, batch, seq)
                      for i, k in enumerate(pat[: cfg.n_rem_layers])}
    return out


def init_cache(cfg: ArchConfig, batch: int, seq: int) -> Dict:
    def mk(s: jax.ShapeDtypeStruct):
        if s.dtype == jnp.int32:            # kpos arrays start invalid
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(mk, cache_shapes(cfg, batch, seq))


def pad_caches(cfg: ArchConfig, caches: Dict, extra: int) -> Dict:
    """Extend dense KV caches by ``extra`` sequence slots (post-prefill, so
    decode can append).  Ring / recurrent / cross caches are size-invariant.
    """
    def pad_block(name: str, block: Dict) -> Dict:
        kind = name.split("_", 1)[1]
        if kind not in ("attn", "moe", "dec"):
            return block
        out = dict(block)
        for key in ("k", "v"):
            arr = block[key]
            pads = [(0, 0)] * arr.ndim
            pads[arr.ndim - 3] = (0, extra)   # (..., S, Hkv, Dh)
            out[key] = jnp.pad(arr, pads)
        return out

    new: Dict = {}
    for sect in caches:
        new[sect] = {n: pad_block(n, b) for n, b in caches[sect].items()}
    return new

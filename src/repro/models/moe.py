"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Expert-parallel by construction: expert tensors carry the 'expert' logical
axis (sharded over the mesh 'model' axis), so GSPMD turns the dispatch
gather/scatter into the all-to-all pattern of classic EP.

Routing/ranking runs **per batch row** (argsort along the T·K axis of each
sequence): the batch axis stays data-sharded, so position-in-expert ranking
never triggers a cross-shard sort/all-gather — capacity is per-sequence,
matching per-device capacity semantics of deployed EP systems.  Tokens over
an expert's capacity are dropped (Switch/GShard semantics) during training;
decode (T == 1) is dropless.  The router aux loss balances load.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import cdt


def moe_ffn(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, D) -> (out, aux_loss)."""
    dt = cdt(cfg)
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    nk = T * K

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (B, T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)            # (B, T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary loss (Switch-style) ---
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (B * T * K))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # --- per-row position-in-expert ranking (shard-local) ---
    flat_e = expert_ids.reshape(B, nk)                         # (B, T*K)
    flat_g = gate_vals.reshape(B, nk)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    newrun = jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    idx = jnp.broadcast_to(jnp.arange(nk)[None], (B, nk))
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(newrun, idx, 0), axis=1)
    rank_sorted = (idx - run_start).astype(jnp.int32)
    pos_in_e = jnp.zeros((B, nk), jnp.int32).at[
        jnp.arange(B)[:, None], order].set(rank_sorted)

    if T == 1:
        cap = nk          # decode: dropless (nk = K slots per row)
    else:
        cap = max(1, int(nk * cfg.capacity_factor / E))
    keep = pos_in_e < cap
    slot = jnp.where(keep, pos_in_e, 0)
    tok_idx = jnp.broadcast_to(
        (jnp.arange(nk) // K)[None], (B, nk))                  # token per slot
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, nk))

    toks = jnp.take_along_axis(
        x.astype(dt), tok_idx[..., None], axis=1)              # (B, T*K, D)
    disp = jnp.zeros((B, E, cap, D), dt).at[bidx, flat_e, slot].add(
        jnp.where(keep[..., None], toks, 0))

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", disp, p["wg"].astype(dt)))
    h = h * jnp.einsum("becd,edf->becf", disp, p["wi"].astype(dt))
    y = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt))    # (B, E, C, D)

    gathered = y[bidx, flat_e, slot]                           # (B, T*K, D)
    contrib = gathered * (flat_g * keep).astype(dt)[..., None]
    out = jnp.zeros((B, T, D), dt).at[bidx, tok_idx].add(contrib)
    return out, aux

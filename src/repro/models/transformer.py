"""Model assembly: block dispatch, scan-over-groups, forward/prefill/decode.

Layers are stacked per *pattern group* (e.g. RecurrentGemma's (rglru, rglru,
local) triple) and iterated with ``jax.lax.scan`` so compile time and HLO
size stay O(1) in depth; remainder layers (26 = 8·3 + 2) run unrolled.
Training wraps the scanned body in ``jax.checkpoint`` per the config's remat
policy.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as L
from . import moe as M
from . import rglru as RG
from . import rwkv6 as RW


# ---------------------------------------------------------------------------
# Single block application
# ---------------------------------------------------------------------------

def _seq_shard_constraint(cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Sequence-parallel residual layout (cfg.seq_shard; §Perf).

    Resolved against the ambient mesh: tries the multi-pod spec first, then
    single-pod; outside any mesh context the flag is a no-op."""
    if not cfg.seq_shard or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P
    for spec in (P(("pod", "data"), "model", None),
                 P("data", "model", None)):
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            continue
    return x


def apply_block(cfg: ArchConfig, kind: str, p: Dict, x: jnp.ndarray,
                ctx: Dict[str, Any], cache: Optional[Dict],
                ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x, new_cache_or_None, aux_loss)."""
    mode = ctx["mode"]              # train | prefill | decode
    impl = ctx.get("impl", "xla")
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict] = None
    x = _seq_shard_constraint(cfg, x)

    if kind in ("attn", "local", "moe", "enc", "dec"):
        h = L.norm(cfg, p["ln1"], x)
        window = cfg.window if kind == "local" else None
        causal = kind != "enc"
        if mode == "decode":
            a, kv_new = L.decode_attention(cfg, p["attn"], h, cache,
                                           ctx["pos"], window=window)
            new_cache = dict(cache)
            new_cache.update(kv_new)
        else:
            a, kv = L.attention(cfg, p["attn"], h,
                                positions=ctx["positions"], causal=causal,
                                window=window, impl=impl)
            if mode == "prefill" and kind != "enc":
                new_cache = _build_cache(cfg, kind, kv, cache, window)
        x = x + a
        if kind == "dec":
            h = L.norm(cfg, p["lnx"], x)
            if mode == "decode":
                a, _ = L.cross_attention(cfg, p["xattn"], h, h,
                                         impl=impl,
                                         kv=(cache["xk"], cache["xv"]))
            else:
                a, xkv = L.cross_attention(cfg, p["xattn"], h,
                                           ctx["enc_out"], impl=impl)
                if mode == "prefill":
                    new_cache["xk"] = xkv["k"].astype(jnp.bfloat16)
                    new_cache["xv"] = xkv["v"].astype(jnp.bfloat16)
            x = x + a
        h = L.norm(cfg, p["ln2"], x)
        if kind == "moe":
            f, aux = M.moe_ffn(cfg, p["moe"], h)
        else:
            f = L.mlp(cfg, p["mlp"], h)
        return x + f, new_cache, aux

    if kind == "cross":
        h = L.norm(cfg, p["ln1"], x)
        if mode == "decode":
            a, _ = L.cross_attention(cfg, p["xattn"], h, h,
                                     impl=impl, kv=(cache["k"], cache["v"]))
            new_cache = cache
        else:
            a, xkv = L.cross_attention(cfg, p["xattn"], h, ctx["img"],
                                       impl=impl)
            if mode == "prefill":
                new_cache = {"k": xkv["k"].astype(jnp.bfloat16),
                             "v": xkv["v"].astype(jnp.bfloat16)}
        gate = jnp.tanh(p["gate"].astype(x.dtype))
        x = x + gate * a
        h = L.norm(cfg, p["ln2"], x)
        return x + L.mlp(cfg, p["mlp"], h), new_cache, aux

    if kind == "rglru":
        h = L.norm(cfg, p["ln1"], x)
        rec_cache = None
        if mode != "train":
            rec_cache = cache if cache is not None else _zero_rec(cfg, x)
        a, rec_new = RG.rglru_block(cfg, p["rec"], h, cache=rec_cache)
        x = x + a
        h = L.norm(cfg, p["ln2"], x)
        return x + L.mlp(cfg, p["mlp"], h), rec_new, aux

    if kind == "rwkv":
        h = L.norm(cfg, p["ln1"], x)
        if mode == "decode":
            a, s_new, sh_t = RW.rwkv_time_mix_step(
                cfg, p["mix"], h, state=cache["s"],
                shift_prev=cache["shift_t"])
        else:
            st = cache["s"] if (mode == "prefill" and cache is not None) \
                else None
            sp = cache["shift_t"] if (mode == "prefill" and cache is not None
                                      ) else None
            a, s_new, sh_t = RW.rwkv_time_mix(cfg, p["mix"], h, state=st,
                                              shift_prev=None)
        x = x + a
        h = L.norm(cfg, p["ln2"], x)
        sp_c = cache["shift_c"] if (mode == "decode") else None
        f, sh_c = RW.rwkv_channel_mix(cfg, p["mix"], h, shift_prev=sp_c)
        x = x + f
        new_cache = None
        if mode != "train":
            new_cache = {"s": s_new, "shift_t": sh_t, "shift_c": sh_c}
        return x, new_cache, aux

    raise ValueError(kind)


def _zero_rec(cfg: ArchConfig, x: jnp.ndarray) -> Dict:
    R = cfg.d_rnn or cfg.d_model
    return {"h": jnp.zeros((x.shape[0], R), jnp.float32),
            "conv": jnp.zeros((x.shape[0], cfg.conv_width - 1, R),
                              jnp.bfloat16)}


def _build_cache(cfg: ArchConfig, kind: str, kv: Dict,
                 proto: Optional[Dict], window: Optional[int]) -> Dict:
    """Turn prefill keys/values (B, T, Hkv, Dh) into the serving cache."""
    k, v = kv["k"].astype(jnp.bfloat16), kv["v"].astype(jnp.bfloat16)
    T = k.shape[1]
    if kind == "local":
        w = window or T              # ring always has `window` slots
        i = jnp.arange(w)
        pidx = (T - 1) - ((T - 1 - i) % w)
        valid = pidx >= 0
        kpos = jnp.where(valid, pidx, -1).astype(jnp.int32)
        safe = jnp.clip(pidx, 0, T - 1)
        return {"k": k[:, safe] * valid[None, :, None, None],
                "v": v[:, safe] * valid[None, :, None, None],
                "kpos": kpos}
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Stack application (scan over groups + unrolled remainder)
# ---------------------------------------------------------------------------

def _remat(cfg: ArchConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def apply_stack(cfg: ArchConfig, params: Dict, x: jnp.ndarray,
                ctx: Dict[str, Any], caches: Optional[Dict] = None,
                pattern: Optional[Tuple[str, ...]] = None,
                prefix: str = "b",
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Returns (x, aux_total, new_caches)."""
    pat = pattern if pattern is not None else cfg.pattern
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict = {}
    train = ctx["mode"] == "train"

    if "groups" in params:
        names = [f"{prefix}{i}_{k}" for i, k in enumerate(pat)]
        gp = tuple(params["groups"][n] for n in names)
        gc = tuple(caches["groups"][n] for n in names) if caches else None

        def body(carry, xs):
            h, aux = carry
            ps = xs[0]
            cs = xs[1] if caches else (None,) * len(pat)
            outs = []
            for i, kind in enumerate(pat):
                h, c_new, a = apply_block(cfg, kind, ps[i], h, ctx, cs[i])
                aux = aux + a
                outs.append(c_new)
            return (h, aux), (tuple(outs) if caches or ctx["mode"] ==
                              "prefill" else None)

        n_groups = jax.tree.leaves(gp)[0].shape[0]
        if cfg.cost_exact:
            # unrolled (cost-probe mode): cost_analysis sees every layer
            ys_list = []
            for g in range(n_groups):
                xs_g = (jax.tree.map(lambda a: a[g], gp),) + (
                    (jax.tree.map(lambda a: a[g], gc),) if caches else ())
                (x, aux_total), y = body((x, aux_total), xs_g)
                ys_list.append(y)
            ys = jax.tree.map(lambda *a: jnp.stack(a), *ys_list) \
                if ys_list and ys_list[0] is not None else None
        else:
            body_fn = _remat(cfg, body) if train else body
            xs = (gp, gc) if caches else (gp,)
            (x, aux_total), ys = jax.lax.scan(body_fn, (x, aux_total), xs)
        if ys is not None:
            new_caches["groups"] = {n: ys[i] for i, n in enumerate(names)}

    if "rem" in params:
        new_caches.setdefault("rem", {})
        for i, kind in enumerate(pat[: cfg.n_rem_layers]):
            n = f"r{i}_{kind}"
            c = caches["rem"][n] if caches else None
            x, c_new, a = apply_block(cfg, kind, params["rem"][n], x, ctx, c)
            aux_total = aux_total + a
            if c_new is not None:
                new_caches["rem"][n] = c_new
        if not new_caches["rem"]:
            new_caches.pop("rem")

    return x, aux_total, (new_caches if new_caches else None)


# ---------------------------------------------------------------------------
# Embedding / logits / model-level entry points
# ---------------------------------------------------------------------------

def embed(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["embed"]["tok"].astype(L.cdt(cfg))[tokens]


def logits_fn(cfg: ArchConfig, params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.tie_embeddings:
        return xf @ params["embed"]["tok"].astype(jnp.float32).T
    return xf @ params["unembed"]["w"].astype(jnp.float32)


def _context(cfg: ArchConfig, params: Dict, batch: Dict, mode: str,
             impl: str) -> Dict[str, Any]:
    """Modality frontends.  In decode mode the cross K/V live in the cache,
    so neither the image projection nor the encoder is recomputed."""
    ctx: Dict[str, Any] = {"mode": mode, "impl": impl}
    if cfg.cost_exact and impl == "xla":
        ctx["impl"] = "xla_unroll"
    if mode == "decode":
        return ctx
    if "image_embeds" in batch:
        img = batch["image_embeds"].astype(L.cdt(cfg))
        ctx["img"] = img @ params["img_proj"]["w"].astype(L.cdt(cfg))
    if "audio_embeds" in batch:
        enc = params["encoder"]
        h = batch["audio_embeds"].astype(L.cdt(cfg)) @ \
            enc["in_proj"]["w"].astype(L.cdt(cfg))
        ectx = {"mode": "train", "impl": impl,
                "positions": jnp.arange(h.shape[1])}
        h, _, _ = apply_stack(cfg, enc, h, ectx, pattern=("enc",))
        ctx["enc_out"] = L.norm(cfg, enc["final_norm"], h)
    return ctx


def forward_hidden(cfg: ArchConfig, params: Dict, batch: Dict, *,
                   impl: str = "xla") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Backbone forward: returns (final-norm hidden (B,T,D), aux_loss)."""
    tokens = batch["tokens"]
    x = embed(cfg, params, tokens)
    ctx = _context(cfg, params, batch, "train", impl)
    ctx["positions"] = jnp.arange(tokens.shape[1])
    x, aux, _ = apply_stack(cfg, params, x, ctx)
    return L.norm(cfg, params["final_norm"], x), aux


def forward(cfg: ArchConfig, params: Dict, batch: Dict, *,
            impl: str = "xla") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward: returns (logits (B,T,V) fp32, aux_loss)."""
    x, aux = forward_hidden(cfg, params, batch, impl=impl)
    return logits_fn(cfg, params, x), aux


def prefill(cfg: ArchConfig, params: Dict, batch: Dict, *,
            impl: str = "xla") -> Tuple[jnp.ndarray, Dict]:
    """Prefill: returns (last-position logits (B,V), caches)."""
    tokens = batch["tokens"]
    x = embed(cfg, params, tokens)
    ctx = _context(cfg, params, batch, "prefill", impl)
    ctx["positions"] = jnp.arange(tokens.shape[1])
    x, _, caches = apply_stack(cfg, params, x, ctx)
    x = L.norm(cfg, params["final_norm"], x[:, -1:])
    return logits_fn(cfg, params, x)[:, 0], caches


def decode_step(cfg: ArchConfig, params: Dict, caches: Dict,
                tokens: jnp.ndarray, pos: jnp.ndarray, batch: Dict, *,
                impl: str = "xla") -> Tuple[jnp.ndarray, Dict]:
    """One decode step.  tokens: (B, 1); pos: scalar absolute position."""
    x = embed(cfg, params, tokens)
    ctx = _context(cfg, params, batch, "decode", impl)
    ctx["pos"] = pos
    x, _, new_caches = apply_stack(cfg, params, x, ctx, caches=caches)
    x = L.norm(cfg, params["final_norm"], x)
    return logits_fn(cfg, params, x)[:, 0], new_caches

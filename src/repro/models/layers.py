"""Shared neural layers: norms, rotary embeddings, attention, MLP.

All functions are pure; parameters are dict subtrees produced by
``specs.block_specs``.  Compute dtype is bf16 (params are fp32 and cast at
use); softmax/normalization run in fp32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels.flash_attention import ops as fa_ops


def cdt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype_compute == "bfloat16" else jnp.float32


def norm(cfg: ArchConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"] + p["bias"]
    else:
        y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + 1e-6)
        y = y * p["scale"]
    return y.astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float) -> jnp.ndarray:
    """x: (B, T, H, Dh); positions: (T,) or (B, T) absolute positions."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freq[None, :]
        ang = ang[None, :, None, :]                      # (1, T, 1, half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freq
        ang = ang[:, :, None, :]                         # (B, T, 1, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def _proj_qkv(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
              src: Optional[jnp.ndarray] = None):
    dt = cdt(cfg)
    src = x if src is None else src
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def attention(cfg: ArchConfig, p: Dict, x: jnp.ndarray, *,
              positions: jnp.ndarray, causal: bool = True,
              window: Optional[int] = None,
              impl: str = "xla") -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence self attention (train / prefill).

    Returns (output, {"k","v"} roped keys/values for cache construction).
    """
    dt = cdt(cfg)
    q, k, v = _proj_qkv(cfg, p, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                               impl=impl)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(dt))
    return out, {"k": k, "v": v}


def cross_attention(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
                    kv_src: jnp.ndarray, *,
                    impl: str = "xla",
                    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    ) -> Tuple[jnp.ndarray, Dict]:
    """Cross attention to a fixed memory (image embeds / encoder output)."""
    dt = cdt(cfg)
    if kv is None:
        _, k, v = _proj_qkv(cfg, p, kv_src, src=kv_src)
    else:
        k, v = kv
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    o = fa_ops.flash_attention(q, k, v, causal=False, impl=impl)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(dt))
    return out, {"k": k, "v": v}


def decode_attention(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
                     cache: Dict, pos: jnp.ndarray, *,
                     window: Optional[int] = None) -> Tuple[jnp.ndarray, Dict]:
    """Single-token attention against a KV cache.

    ``cache``: {"k","v"}: (B, S, Hkv, Dh) dense, plus "kpos" (S,) for ring
    (windowed) caches.  The new token is written at index ``pos`` (dense) or
    ``pos % W`` (ring) before attending.
    """
    dt = cdt(cfg)
    b = x.shape[0]
    q, k_new, v_new = _proj_qkv(cfg, p, x)       # T == 1
    q = rope(q, pos[None] if pos.ndim == 0 else pos, cfg.rope_theta)
    k_new = rope(k_new, pos[None] if pos.ndim == 0 else pos, cfg.rope_theta)
    S = cache["k"].shape[1]
    slot = pos % S if window is not None else jnp.minimum(pos, S - 1)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    if window is not None:
        kpos = jax.lax.dynamic_update_slice_in_dim(
            cache["kpos"], pos[None].astype(jnp.int32), slot, axis=0)
        mask = (kpos <= pos) & (kpos > pos - window) & (kpos >= 0)
    else:
        kpos = None
        mask = jnp.arange(S) <= pos
    # dense masked attention over the cache (T=1)
    g = cfg.n_heads // k.shape[2]
    qq = q.reshape(b, 1, k.shape[2], g, q.shape[-1]).astype(jnp.float32)
    sc = jnp.einsum("bthgd,bshd->bhgts", qq, k.astype(jnp.float32))
    sc = sc / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    sc = jnp.where(mask[None, None, None, None, :], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgts,bshd->bthgd", pr, v.astype(jnp.float32))
    o = o.reshape(b, 1, cfg.n_heads, q.shape[-1]).astype(dt)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(dt))
    new_cache = {"k": k, "v": v}
    if kpos is not None:
        new_cache["kpos"] = kpos
    return out, new_cache


def mlp(cfg: ArchConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    dt = cdt(cfg)
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
        return h @ p["wo"].astype(dt)
    h = jax.nn.gelu(x @ p["wi"].astype(dt) + p["bi"].astype(dt))
    return h @ p["wo"].astype(dt) + p["bo"].astype(dt)

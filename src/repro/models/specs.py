"""Parameter specifications: shapes + logical sharding axes per architecture.

The whole parameter tree of any assigned architecture is described *as data*
(``ParamSpec`` leaves), so `jax.eval_shape` is never needed for the dry-run:
shapes, shardings and parameter counts are all derived directly from specs.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..compat import tree_flatten_with_path
from ..configs.base import ArchConfig

Logical = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Logical
    init: str = "normal"      # normal | zeros | ones | lru
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _norm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = {"scale": ParamSpec((cfg.d_model,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
    return d


def _attn_specs(cfg: ArchConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    D, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    kvh = H if cross and cfg.encoder_decoder else Hkv
    s = {
        "wq": ParamSpec((D, H, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, kvh, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, kvh, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, dh, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, dh), ("heads", "head_dim"), "zeros")
        s["bk"] = ParamSpec((kvh, dh), ("kv_heads", "head_dim"), "zeros")
        s["bv"] = ParamSpec((kvh, dh), ("kv_heads", "head_dim"), "zeros")
    return s


def _mlp_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    D, F = cfg.d_model, cfg.d_ff
    s = {"wi": ParamSpec((D, F), ("embed", "mlp")),
         "wo": ParamSpec((F, D), ("mlp", "embed"))}
    if cfg.act == "silu":
        s["wg"] = ParamSpec((D, F), ("embed", "mlp"))
    else:  # gelu with biases (whisper-style)
        s["bi"] = ParamSpec((F,), ("mlp",), "zeros")
        s["bo"] = ParamSpec((D,), ("embed",), "zeros")
    return s


def _moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    D, Fe, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((D, E), ("embed", "expert")),
        "wi": ParamSpec((E, D, Fe), ("expert", "embed", "mlp")),
        "wg": ParamSpec((E, D, Fe), ("expert", "embed", "mlp")),
        "wo": ParamSpec((E, Fe, D), ("expert", "mlp", "embed")),
    }


def _rglru_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    D, R, CW = cfg.d_model, cfg.d_rnn or cfg.d_model, cfg.conv_width
    return {
        "wx": ParamSpec((D, R), ("embed", "rnn")),
        "wy": ParamSpec((D, R), ("embed", "rnn")),
        "conv_w": ParamSpec((CW, R), ("conv", "rnn")),
        "conv_b": ParamSpec((R,), ("rnn",), "zeros"),
        "lam": ParamSpec((R,), ("rnn",), "lru"),
        "wa": ParamSpec((R, R), ("rnn_in", "rnn")),
        "wi": ParamSpec((R, R), ("rnn_in", "rnn")),
        "wout": ParamSpec((R, D), ("rnn", "embed")),
    }


def _rwkv_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    D, F = cfg.d_model, cfg.d_ff
    s: Dict[str, ParamSpec] = {}
    for mu in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
        s[mu] = ParamSpec((D,), ("embed",), "zeros")
    for w in ("wr", "wk", "wv", "wg"):
        s[w] = ParamSpec((D, D), ("embed", "rnn"))
    s["ww"] = ParamSpec((D, D), ("embed", "rnn"), scale=0.002)
    s["w_bias"] = ParamSpec((D,), ("rnn",), "lru")
    s["u"] = ParamSpec((D,), ("rnn",), "zeros")
    s["wo"] = ParamSpec((D, D), ("rnn", "embed"))
    s["gn_scale"] = ParamSpec((D,), ("rnn",), "ones")
    # channel mix
    s["c_mu_k"] = ParamSpec((D,), ("embed",), "zeros")
    s["c_mu_r"] = ParamSpec((D,), ("embed",), "zeros")
    s["c_wk"] = ParamSpec((D, F), ("embed", "mlp"))
    s["c_wv"] = ParamSpec((F, D), ("mlp", "embed"))
    s["c_wr"] = ParamSpec((D, D), ("embed", "rnn"))
    return s


def block_specs(cfg: ArchConfig, kind: str) -> Dict:
    """Specs of one transformer block of the given kind."""
    if kind in ("attn", "local"):
        return {"ln1": _norm_specs(cfg), "attn": _attn_specs(cfg),
                "ln2": _norm_specs(cfg), "mlp": _mlp_specs(cfg)}
    if kind == "moe":
        return {"ln1": _norm_specs(cfg), "attn": _attn_specs(cfg),
                "ln2": _norm_specs(cfg), "moe": _moe_specs(cfg)}
    if kind == "cross":
        return {"ln1": _norm_specs(cfg), "xattn": _attn_specs(cfg, cross=True),
                "gate": ParamSpec((1,), (None,), "zeros"),
                "ln2": _norm_specs(cfg), "mlp": _mlp_specs(cfg)}
    if kind == "rglru":
        return {"ln1": _norm_specs(cfg), "rec": _rglru_specs(cfg),
                "ln2": _norm_specs(cfg), "mlp": _mlp_specs(cfg)}
    if kind == "rwkv":
        return {"ln1": _norm_specs(cfg), "ln2": _norm_specs(cfg),
                "mix": _rwkv_specs(cfg)}
    if kind == "enc":
        return {"ln1": _norm_specs(cfg), "attn": _attn_specs(cfg),
                "ln2": _norm_specs(cfg), "mlp": _mlp_specs(cfg)}
    if kind == "dec":
        return {"ln1": _norm_specs(cfg), "attn": _attn_specs(cfg),
                "lnx": _norm_specs(cfg), "xattn": _attn_specs(cfg, cross=True),
                "ln2": _norm_specs(cfg), "mlp": _mlp_specs(cfg)}
    raise ValueError(kind)


def _stack(tree, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical,
                            s.init, s.scale), tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def model_specs(cfg: ArchConfig) -> Dict:
    """Full parameter tree spec for an architecture."""
    D, V = cfg.d_model, cfg.vocab
    specs: Dict = {
        "embed": {"tok": ParamSpec((V, D), ("vocab", "embed"))},
        "final_norm": _norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = {"w": ParamSpec((D, V), ("embed", "vocab"))}
    pat = cfg.pattern
    if cfg.n_groups > 0:
        specs["groups"] = {f"b{i}_{k}": _stack(block_specs(cfg, k),
                                               cfg.n_groups)
                           for i, k in enumerate(pat)}
    if cfg.n_rem_layers:
        specs["rem"] = {f"r{i}_{k}": block_specs(cfg, k)
                        for i, k in enumerate(pat[: cfg.n_rem_layers])}
    if cfg.family == "vlm":
        specs["img_proj"] = {"w": ParamSpec((D, D), ("embed", "embed_out"))}
    if cfg.encoder_decoder:
        ne = cfg.n_encoder_layers
        specs["encoder"] = {
            "groups": {"b0_enc": _stack(block_specs(cfg, "enc"), ne)},
            "final_norm": _norm_specs(cfg),
            "in_proj": {"w": ParamSpec((D, D), ("embed", "embed_out"))},
        }
    return specs


# ---------------------------------------------------------------------------
def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def expert_params(cfg: ArchConfig) -> Tuple[int, int]:
    """(total expert params over all moe layers, per-expert-per-layer)."""
    per = 3 * cfg.d_model * cfg.d_ff
    n_moe = sum(1 for k in cfg.layer_kinds() if k == "moe")
    return per * cfg.n_experts * n_moe, per


def spec_shapes(specs) -> Dict:
    """ShapeDtypeStructs (fp32 params) matching the spec tree."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), specs,
        is_leaf=is_spec)


def logical_axes(specs) -> Dict:
    return jax.tree.map(lambda s: s.logical, specs, is_leaf=is_spec)


def _init_leaf(s: ParamSpec, key) -> jnp.ndarray:
    if s.init == "zeros":
        return jnp.zeros(s.shape, jnp.float32)
    if s.init == "ones":
        return jnp.ones(s.shape, jnp.float32)
    if s.init == "lru":
        # Λ such that RG-LRU decay starts in ~[0.9, 0.999]
        u = jax.random.uniform(key, s.shape, jnp.float32, -8.0, -4.0)
        return u
    return jax.random.normal(key, s.shape, jnp.float32) * s.scale


def init_params(specs, key) -> Dict:
    """Deterministic init: every leaf gets a key derived from its path."""
    flat, treedef = tree_flatten_with_path(specs, is_leaf=is_spec)
    leaves = []
    for path, s in flat:
        name = "/".join(str(p) for p in path)
        h = int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "big")
        leaves.append(_init_leaf(s, jax.random.fold_in(key, h)))
    return jax.tree.unflatten(treedef, leaves)

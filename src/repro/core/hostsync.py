"""Deliberate device→host synchronization funnel + the async emit queue.

Every host sync on the join-engine hot path goes through :func:`device_get`
so the cost that used to be invisible (``bool(F.valid.any())`` per chunk,
``int(...)`` per stat) is a *counted event*: tests put a :class:`SyncCounter`
around a query and assert the executor stays under a fixed budget
(``tests/test_sync_budget.py``).  The schedule executor batches its
admission checks so the count is O(ops), not O(chunks).

**Async fetches (DESIGN.md §2.8).**  Evaluation-mode emission used to drain
every result block with one blocking fetch at pass end — the device idled
while the host copied.  :func:`device_get_async` instead *issues* the
device→host copy (``jax.Array.copy_to_host_async``) and returns an
:class:`AsyncFetch` handle; the copy proceeds in the background while the
executor keeps launching the next morsel's work.  :class:`AsyncFetchQueue`
bounds how many fetches may be in flight (device buffers pinned per
in-flight block) and preserves FIFO arrival order.

Accounting rules (budget-tested):

* ``SyncCounter.count`` counts **blocking** syncs only — the number that
  must stay O(ops).
* an async *issue* increments ``SyncCounter.async_count`` and rides
  ``events``/``label_counts`` under its own label (e.g. ``emit-stream``),
  so in-flight fetches are visible separately and a test can pin their
  frequency without conflating them with blocking syncs.
* *completing* an async fetch (``AsyncFetch.get``) is not a counted event:
  the copy was issued — and accounted — when the handle was created.
* counter scopes are **thread-local**: a ``SyncCounter`` only observes
  syncs issued by the thread that entered it (the serving layer budgets
  each session's worker-thread execution independently).
"""
from __future__ import annotations

import threading
from collections import Counter, deque
from typing import Any, Deque, Iterator, List

import jax

# Counter scopes are PER THREAD: the serving layer (repro/serve) runs many
# client sessions against one process, and a SyncCounter opened around one
# session's query must not absorb syncs issued by another thread's work.
_tls = threading.local()


def _active() -> List["SyncCounter"]:
    lst = getattr(_tls, "counters", None)
    if lst is None:
        lst = _tls.counters = []
    return lst


class SyncCounter:
    """Context manager counting device→host syncs made through this funnel.

    ``count`` is the number of blocking :func:`device_get` calls (each call
    may fetch a whole pytree — that is the point: one batched fetch per op,
    not one per chunk).  ``async_count`` is the number of
    :func:`device_get_async` issues (non-blocking; the copy overlaps device
    work).  ``events`` records the labels of both, for diagnosing
    regressions; ``label_counts`` is the same information aggregated, so
    budget tests can pin one label's frequency (e.g. the evaluation-mode
    payload plan must ride the per-fold ``replay-plan`` fetch — O(ops),
    not O(hits) — and streaming emission must issue ``emit-stream``
    fetches asynchronously, never as blocking syncs).
    """

    def __init__(self) -> None:
        self.count = 0
        self.async_count = 0
        self.events: List[str] = []
        self.label_counts: Counter = Counter()

    def __enter__(self) -> "SyncCounter":
        _active().append(self)
        return self

    def __exit__(self, *exc) -> bool:
        _active().remove(self)
        return False


def device_get(tree: Any, label: str = "") -> Any:
    """``jax.device_get`` with sync accounting (one event per call)."""
    for c in _active():
        c.count += 1
        c.events.append(label)
        c.label_counts[label] += 1
    return jax.device_get(tree)


# ---------------------------------------------------------------------------
# Async fetches (streaming emit — DESIGN.md §2.8)
# ---------------------------------------------------------------------------


class AsyncFetch:
    """Handle for one issued (in-flight) device→host copy of a pytree.

    Created by :func:`device_get_async`; :meth:`get` materializes the host
    values (fast once the background copy has landed).  Completion is not
    a counted sync — the fetch was accounted at issue time."""

    __slots__ = ("tree", "label")

    def __init__(self, tree: Any, label: str):
        self.tree = tree
        self.label = label

    def ready(self) -> bool:
        """Best-effort readiness: True once every leaf's *producing
        computation* has completed (``jax.Array.is_ready``).  The D2H
        copy issued at creation usually lands with it, but JAX exposes no
        copy-completion signal, so :meth:`get` may still briefly block on
        the transfer itself — ``ready()`` is a scheduling hint (used by
        ``poll`` to avoid obviously-blocking pops), not a no-block
        guarantee."""
        for leaf in jax.tree.leaves(self.tree):
            if isinstance(leaf, jax.Array) and not leaf.is_ready():
                return False
        return True

    def get(self) -> Any:
        return jax.device_get(self.tree)


def device_get_async(tree: Any, label: str = "") -> AsyncFetch:
    """Issue a non-blocking device→host copy of ``tree``.

    Starts ``copy_to_host_async`` on every ``jax.Array`` leaf and returns
    an :class:`AsyncFetch`.  Counted as an *async* event (see the module
    docstring's accounting rules): ``SyncCounter.async_count`` and
    ``label_counts[label]`` advance, ``count`` does not."""
    for leaf in jax.tree.leaves(tree):
        if isinstance(leaf, jax.Array):
            try:
                leaf.copy_to_host_async()
            except (NotImplementedError, AttributeError):
                # backend without D2H async: .get() still works, it just
                # blocks on the transfer.  Real failures (deleted/donated
                # buffers, ...) must surface HERE, not at some later
                # unrelated .get() — so only the unsupported cases pass.
                pass
    for c in _active():
        c.async_count += 1
        c.events.append(label)
        c.label_counts[label] += 1
    return AsyncFetch(tree, label)


class AsyncFetchQueue:
    """Bounded FIFO of in-flight async fetches (the streaming emit queue).

    ``put`` issues a new fetch; when the bound is reached the *oldest*
    fetch is completed first (back-pressure: at most ``max_in_flight``
    device blocks are pinned by emission at any moment).  ``poll`` pops
    fetches whose copies have already landed without blocking; ``drain``
    completes everything.  All three return host pytrees in issue order,
    so a consumer that concatenates ``put``/``poll``/``drain`` results
    sees blocks in exact production order."""

    def __init__(self, max_in_flight: int = 8):
        if max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        self.max_in_flight = int(max_in_flight)
        self._q: Deque[AsyncFetch] = deque()
        self.issued = 0
        self.high_water = 0  # max simultaneous in-flight fetches observed

    @property
    def in_flight(self) -> int:
        return len(self._q)

    def put(self, tree: Any, label: str = "") -> List[Any]:
        """Issue one fetch; returns the host values of any fetches that had
        to be completed to stay under the in-flight bound (oldest first,
        possibly empty)."""
        done: List[Any] = []
        while len(self._q) >= self.max_in_flight:
            done.append(self._q.popleft().get())
        self._q.append(device_get_async(tree, label))
        self.issued += 1
        self.high_water = max(self.high_water, len(self._q))
        return done

    def poll(self) -> List[Any]:
        """Pop fetches from the head whose producing computation has
        landed (see :meth:`AsyncFetch.ready` for what that does and does
        not guarantee).  FIFO: a ready fetch behind a still-flying one
        stays queued — order is part of the contract."""
        done: List[Any] = []
        while self._q and self._q[0].ready():
            done.append(self._q.popleft().get())
        return done

    def drain(self) -> Iterator[Any]:
        """Complete every remaining fetch, oldest first."""
        while self._q:
            yield self._q.popleft().get()

"""Deliberate device→host synchronization funnel.

Every host sync on the join-engine hot path goes through :func:`device_get`
so the cost that used to be invisible (``bool(F.valid.any())`` per chunk,
``int(...)`` per stat) is a *counted event*: tests put a :class:`SyncCounter`
around a query and assert the executor stays under a fixed budget
(``tests/test_sync_budget.py``).  The schedule executor batches its
admission checks so the count is O(ops), not O(chunks).
"""
from __future__ import annotations

from collections import Counter
from typing import Any, List

import jax

_active: List["SyncCounter"] = []


class SyncCounter:
    """Context manager counting device→host syncs made through this funnel.

    ``count`` is the number of :func:`device_get` calls (each call may fetch
    a whole pytree — that is the point: one batched fetch per op, not one
    per chunk).  ``events`` records the labels, for diagnosing regressions;
    ``label_counts`` is the same information aggregated, so budget tests
    can pin one label's frequency (e.g. the evaluation-mode payload plan
    must ride the per-fold ``replay-plan`` fetch: O(ops), not O(hits)).
    """

    def __init__(self) -> None:
        self.count = 0
        self.events: List[str] = []
        self.label_counts: Counter = Counter()

    def __enter__(self) -> "SyncCounter":
        _active.append(self)
        return self

    def __exit__(self, *exc) -> bool:
        _active.remove(self)
        return False


def device_get(tree: Any, label: str = "") -> Any:
    """``jax.device_get`` with sync accounting (one event per call)."""
    for c in _active:
        c.count += 1
        c.events.append(label)
        c.label_counts[label] += 1
    return jax.device_get(tree)

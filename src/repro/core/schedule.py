"""Execution IR for the vectorized trie join: one schedule, many engines.

The CLFTJ control flow (paper Fig 2) used to be re-derived three times —
host recursion in ``frontier.py``, the cache-aware copy in
``cached_frontier.py``, and the statically-unrolled variant in
``distributed.py``.  Following Free Join's plan/execution split and
Veldhuizen's view of LFTJ as a composition of per-variable iterator ops,
this module lowers ``(CQ, TreeDecomposition, order)`` into a *linear
instruction schedule* over four ops:

  * ``EXPAND(d)``        — frontier expansion of order variable ``x_d``
  * ``ENTER_CHILD(c)``   — TD-node entry: tier-2 probe + tier-1 dedup,
                           parent chunk parked on an explicit frame stack
  * ``FOLD_CHILD(c)``    — TD-node exit: segment counts, tier-2 insert,
                           factor multiplication (count mode) or replay of
                           representative row blocks through ``orig``
                           (evaluate mode — the paper §3.4's factorized
                           intermediates, materialized; with
                           ``cache_payloads`` the blocks are also stored
                           in / spliced from the tier-2 slab arena)
  * ``EMIT``             — accumulate counts / yield result tuples

The TD recursion is flattened at lowering time: a subtree's ops are *data*
(a bracketed ``ENTER … FOLD`` span in the op list), not Python call frames.
Executors:

  * :class:`ScheduleExecutor` — the host-driven engine: morsel splitting,
    pluggable tier-2 cache (``core/cache.py``), batched chunk admission so
    ``valid.any()`` host syncs happen at most once per op execution (not
    per chunk — every sync is routed through :mod:`hostsync` and
    budget-tested), while parent morsels still run an ENTER…FOLD span
    sequentially so later morsels hit earlier morsels' tier-2 inserts.
  * :func:`execute_static` — a trace-time interpreter of the same schedule:
    fixed capacity, overflow flag instead of splitting, functional cache
    tables — one pure function for ``shard_map`` (``distributed.py``).

Cache, dedup, and sharding are therefore *executor capabilities* driven by
op flags, not engine-subclass overrides.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .hostsync import AsyncFetchQueue, device_get

MAX_KEY_BITS = 21  # packed adhesion keys: values must fit in 21 bits

# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------

EXPAND = "expand"
ENTER_CHILD = "enter_child"
FOLD_CHILD = "fold_child"
EMIT = "emit"


@dataclass(frozen=True)
class Op:
    """One schedule instruction (see module docstring for semantics).

    ``probe``/``dedup`` are *eligibility* flags resolved at lowering time
    (key packs into int64, adhesion dim <= 2, node enabled, engine dedup
    setting); the executor still ANDs ``probe`` with its runtime cache
    state (manager enabled, table materialized, count-vs-evaluate mode).
    """

    kind: str
    d: int = -1                      # EXPAND: depth (order position)
    node: int = -1                   # ENTER/FOLD: TD node id
    adhesion: Tuple[int, ...] = ()   # ENTER/FOLD: order positions of α
    probe: bool = False              # ENTER: tier-2 eligible (FOLD: insert)
    dedup: bool = False              # ENTER: tier-1 eligible
    sub_first: int = -1              # FOLD: first depth owned inside t|c
    sub_last: int = -1               # FOLD: last depth owned inside t|c

    def __str__(self) -> str:
        if self.kind == EXPAND:
            return f"EXPAND(d={self.d})"
        if self.kind == ENTER_CHILD:
            return (f"ENTER_CHILD(c={self.node}, α={self.adhesion}, "
                    f"probe={self.probe}, dedup={self.dedup})")
        if self.kind == FOLD_CHILD:
            return (f"FOLD_CHILD(c={self.node}, "
                    f"sub=[{self.sub_first},{self.sub_last}])")
        return "EMIT"


@dataclass(frozen=True)
class Schedule:
    """A lowered, validated linear op list for one (query, TD, order)."""

    ops: Tuple[Op, ...]
    n: int  # number of order variables

    def __post_init__(self):
        depths = [op.d for op in self.ops if op.kind == EXPAND]
        if depths != list(range(self.n)):
            raise ValueError(f"EXPAND depths {depths} != 0..{self.n - 1}")
        if not self.ops or self.ops[-1].kind != EMIT:
            raise ValueError("schedule must end with EMIT")
        stack: List[int] = []
        for op in self.ops:
            if op.kind == ENTER_CHILD:
                stack.append(op.node)
            elif op.kind == FOLD_CHILD:
                if not stack or stack[-1] != op.node:
                    raise ValueError(
                        f"FOLD_CHILD({op.node}) does not match open "
                        f"ENTER stack {stack}")
                stack.pop()
        if stack:
            raise ValueError(f"unclosed ENTER_CHILD nodes {stack}")

    def describe(self) -> str:
        return "\n".join(str(op) for op in self.ops)

    def signature(self) -> str:
        """Stable structural hash of the lowered op list (kind, depth, node,
        adhesion and the eligibility flags of every op).  Two engines with
        equal signatures execute the same instruction stream, so persisted
        tier-2 state keyed by it (``repro/serve/persist.py``) can be
        replayed safely; a lowering change invalidates old snapshots by
        changing the signature, never by corrupting a replay."""
        import hashlib
        parts = [(op.kind, op.d, op.node, op.adhesion, op.probe, op.dedup,
                  op.sub_first, op.sub_last) for op in self.ops]
        blob = repr((self.n, parts)).encode()
        return hashlib.sha256(blob).hexdigest()[:16]


def lower(n: int, plan: Optional[Any] = None,
          cacheable: Optional[Callable[[int], bool]] = None,
          dedup: bool = True) -> Schedule:
    """Compile ``(order length, Plan)`` into a linear schedule.

    ``plan`` is a :class:`~.clftj_ref.Plan` (TD/order correspondence);
    ``plan=None`` lowers the vanilla LFTJ (no TD): EXPAND over every depth
    then EMIT.  ``cacheable(c)`` resolves per-node key eligibility
    (packability, adhesion dimension, enabled_nodes); ``dedup`` is the
    engine's tier-1 switch — both are baked into op flags so every
    executor runs the same gating.
    """
    ops: List[Op] = []
    if plan is None:
        ops.extend(Op(EXPAND, d=d) for d in range(n))
    else:
        can = cacheable if cacheable is not None else (lambda c: False)

        def emit_node(v: int) -> None:
            if v in plan.first_d:
                ops.extend(Op(EXPAND, d=d) for d in
                           range(plan.first_d[v], plan.last_d[v] + 1))
            for c in plan.td.children[v]:
                keyable = bool(can(c))
                adh = tuple(plan.adhesion_idx[c])
                ops.append(Op(ENTER_CHILD, node=c, adhesion=adh,
                              probe=keyable, dedup=keyable and dedup))
                emit_node(c)
                ops.append(Op(FOLD_CHILD, node=c, adhesion=adh,
                              probe=keyable, dedup=keyable and dedup,
                              sub_first=plan.first_d[c],
                              sub_last=plan.subtree_last[c]))

        emit_node(plan.td.root)
    ops.append(Op(EMIT))
    return Schedule(tuple(ops), n)


# ---------------------------------------------------------------------------
# Shared jitted chunk ops (used by every executor; chunk type is any
# Frontier-shaped NamedTuple — assign/factor/valid/orig/lo/hi)
# ---------------------------------------------------------------------------


def _pack_keys(assign: jnp.ndarray, idx: Tuple[int, ...],
               node: int) -> jnp.ndarray:
    """Pack <=2 adhesion columns + node id into one int64 key."""
    key = jnp.full((assign.shape[0],), np.int64(node))
    for i in idx:
        key = (key << MAX_KEY_BITS) | assign[:, i].astype(jnp.int64)
    return key


@jax.jit
def _dedup(keys: jnp.ndarray, active: jnp.ndarray):
    """Unique active keys: returns (first_idx, rep_of_row, n_reps).

    * ``first_idx[r]``   — row index of representative r (garbage for r >=
      n_reps),
    * ``rep_of_row[i]``  — representative id of row i (garbage if inactive),
    * ``n_reps``         — number of distinct active keys.
    """
    C = keys.shape[0]
    big = jnp.int64(2 ** 62)
    k = jnp.where(active, keys, big)  # inactive rows sort to the back
    order = jnp.argsort(k, stable=True)
    ks = k[order]
    isfirst = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    isfirst = isfirst & (ks != big)
    rep_sorted = jnp.cumsum(isfirst.astype(jnp.int32)) - 1
    n_reps = jnp.sum(isfirst.astype(jnp.int32))
    rep_of_row = jnp.zeros((C,), jnp.int32).at[order].set(rep_sorted)
    # first occurrence row index per rep (scatter-max; -1 writes are no-ops)
    first_idx = jnp.zeros((C,), jnp.int32).at[
        jnp.clip(rep_sorted, 0, C - 1)].max(
        jnp.where(isfirst, order, -1).astype(jnp.int32))
    return first_idx, rep_of_row, n_reps


@jax.jit
def _make_rep_frontier(F, first_idx: jnp.ndarray, n_reps: jnp.ndarray):
    C = F.assign.shape[0]
    rep_valid = jnp.arange(C, dtype=jnp.int32) < n_reps
    src = jnp.clip(first_idx, 0, C - 1)
    return F._replace(assign=F.assign[src],
                      factor=jnp.where(rep_valid, 1, 0).astype(jnp.int64),
                      valid=rep_valid,
                      orig=jnp.arange(C, dtype=jnp.int32),
                      lo=F.lo[src], hi=F.hi[src])


@jax.jit
def _identity_reps(F, active: jnp.ndarray):
    """Degenerate dedup: every active row is its own representative."""
    C = F.assign.shape[0]
    return F._replace(factor=jnp.where(active, 1, 0).astype(jnp.int64),
                      valid=active,
                      orig=jnp.arange(C, dtype=jnp.int32))


@jax.jit
def _apply_counts(F, hit, hvals, rep_of_row, cnt):
    mult = jnp.where(hit, hvals, cnt[jnp.clip(rep_of_row, 0, cnt.shape[0] - 1)])
    factor = F.factor * mult
    return F._replace(factor=factor, valid=F.valid & (factor > 0))


@functools.partial(jax.jit, static_argnames=("n_slots",))
def _segment_counts(exit_F, n_slots: int) -> jnp.ndarray:
    contrib = jnp.where(exit_F.valid, exit_F.factor, 0)
    return jnp.zeros((n_slots,), jnp.int64).at[
        jnp.clip(exit_F.orig, 0, n_slots - 1)].add(contrib)


@functools.partial(jax.jit, static_argnames=("d0", "d1"))
def _replay_step(P, active, rep_of_row, E, *, d0: int, d1: int):
    """Scatter one subtree exit chunk back through ``orig`` (evaluate mode).

    For every active parent row *i* (representative ``rep_of_row[i]``) and
    every valid exit row *e* with ``E.orig == rep_of_row[i]``, produce one
    output row: the parent's assignment with the subtree columns
    ``[d0, d1]`` replaced by the exit row's — the factorized intermediate
    of paper §3.4, re-expanded.  Caller guarantees the total pair count
    fits the chunk capacity (``active`` is a pre-packed morsel mask).
    """
    C = P.assign.shape[0]
    # exits per representative, and exit rows sorted by representative id
    ecnt = jnp.zeros((C,), jnp.int32).at[
        jnp.clip(E.orig, 0, C - 1)].add(E.valid.astype(jnp.int32))
    ekey = jnp.where(E.valid, jnp.clip(E.orig, 0, C - 1), jnp.int32(C))
    eorder = jnp.argsort(ekey, stable=True)
    estart = jnp.cumsum(ecnt) - ecnt
    # enumerate (parent, exit) pairs exactly like _expand_step enumerates
    # (row, candidate) pairs: cumsum offsets + searchsorted
    rep = jnp.clip(rep_of_row, 0, C - 1)
    pcnt = jnp.where(active, ecnt[rep], 0).astype(jnp.int32)
    offsets = jnp.cumsum(pcnt) - pcnt
    needed = offsets[-1] + pcnt[-1]
    slot = jnp.arange(C, dtype=jnp.int32)
    src = jnp.clip(jnp.searchsorted(offsets, slot, side="right") - 1, 0, C - 1)
    delta = slot - offsets[src]
    ok = (slot < needed) & (delta < pcnt[src])
    eidx = eorder[jnp.clip(estart[rep[src]] + delta, 0, C - 1)]
    cols = jnp.arange(P.assign.shape[1], dtype=jnp.int32)
    insub = (cols >= d0) & (cols <= d1)
    assign = jnp.where(insub[None, :], E.assign[eidx], P.assign[src])
    out = P._replace(assign=assign,
                     factor=P.factor[src] * E.factor[eidx],
                     valid=ok,
                     orig=P.orig[src],
                     lo=P.lo[src], hi=P.hi[src])
    perm = jnp.argsort(jnp.logical_not(out.valid), stable=True)
    return type(out)(*(x[perm] for x in out)), needed


@functools.partial(jax.jit, static_argnames=("d0", "d1"))
def _store_blocks(slab, E, poff, admit, *, d0: int, d1: int):
    """Write one exit chunk's per-representative row blocks into the slab
    arena (tier-2 payload insert, evaluation mode).

    Exit rows are sorted by representative id exactly as in
    :func:`_replay_step`; rep *r*'s rows land contiguously at ``poff[r]``.
    Refused or invalid rows are routed to the arena's scratch row (the
    last one) — a masked ``.set`` must never target a live slot, or a
    "keep old value" no-op could land after a real write and clobber it.
    """
    C = E.assign.shape[0]
    R = slab.shape[0] - 1  # last row = scratch
    ecnt = jnp.zeros((C,), jnp.int32).at[
        jnp.clip(E.orig, 0, C - 1)].add(E.valid.astype(jnp.int32))
    ekey = jnp.where(E.valid, jnp.clip(E.orig, 0, C - 1), jnp.int32(C))
    eorder = jnp.argsort(ekey, stable=True)
    estart = jnp.cumsum(ecnt) - ecnt
    j = jnp.arange(C, dtype=jnp.int32)
    rep = jnp.clip(E.orig[eorder], 0, C - 1)
    ok = E.valid[eorder] & admit[rep]
    dest = jnp.where(ok, jnp.clip(poff[rep] + (j - estart[rep]), 0, R - 1),
                     R)
    rows = E.assign[eorder, d0:d1 + 1]
    return slab.at[dest].set(jnp.where(ok[:, None], rows, slab[dest]))


@jax.jit
def _merge_compact(A, B):
    """Append chunk B's valid prefix after chunk A's (both valid-prefix
    compacted, as every replay/splice output is).  Returns the merged
    chunk plus the total valid count — the caller flags overflow when it
    exceeds capacity (static executor: no morsel splitting)."""
    C = A.valid.shape[0]
    n1 = jnp.sum(A.valid.astype(jnp.int32))
    n2 = jnp.sum(B.valid.astype(jnp.int32))
    slot = jnp.arange(C, dtype=jnp.int32)
    fromB = slot >= n1
    bidx = jnp.clip(slot - n1, 0, C - 1)

    def pick(a, b):
        m = fromB.reshape((C,) + (1,) * (a.ndim - 1))
        return jnp.where(m, b[bidx], a)

    out = type(A)(*(pick(a, b) for a, b in zip(A, B)))
    return out._replace(valid=slot < jnp.minimum(n1 + n2, C)), n1 + n2


@functools.partial(jax.jit, static_argnames=("cap",))
def _alloc_blocks_static(bump, tplen, lens, cand, *, cap: int):
    """Functional twin of :meth:`~.cache.DeviceCache.alloc_blocks` for the
    trace-time executor: bump-allocate one batch of variable-length slab
    blocks with the arena state (``bump`` pointer, ``tplen`` metadata
    plane) threaded as traced values.  Same rules as the host allocator —
    blocks larger than the whole arena are refused outright; if the batch
    does not fit the remaining arena and the arena is non-empty, every
    payload is epoch-flushed (``tplen`` reset to -1) before admitting;
    candidates still beyond capacity are refused prefix-wise.  Returns
    ``(offsets, admitted, bump', tplen', flushed)``."""
    lens = jnp.where(cand, lens.astype(jnp.int32), 0)
    lens = jnp.where(lens <= cap, lens, 0)
    total = jnp.sum(lens)
    flushed = (total > cap - bump) & (bump > 0) & (total > 0)
    bump = jnp.where(flushed, 0, bump)
    tplen = jnp.where(flushed, jnp.full_like(tplen, -1), tplen)
    cum = jnp.cumsum(lens)
    admit = (lens > 0) & (cum <= cap - bump)
    offs = jnp.where(admit, bump + cum - lens, 0).astype(jnp.int32)
    bump = bump + jnp.sum(jnp.where(admit, lens, 0))
    return offs, admit, bump, tplen, flushed


@functools.partial(jax.jit, static_argnames=("d0", "d1"))
def _splice_step(P, mask, poff, plen, slab, *, d0: int, d1: int):
    """:func:`_replay_step` specialized to slab-resident blocks (splice).

    For every masked parent row *i* with a tier-2 payload hit, emit
    ``plen[i]`` continuation rows: the parent's assignment with the
    subtree columns ``[d0, d1]`` gathered from its cached factorized
    block — the same (parent, exit)-pair enumeration as the replay step,
    with the exit chunk replaced by slab rows (blocks are stored
    contiguously, so no per-rep sort is needed).  Caller guarantees the
    masked total fits the chunk capacity (pre-packed morsel mask).
    """
    C = P.assign.shape[0]
    R = slab.shape[0] - 1
    pcnt = jnp.where(mask, plen, 0).astype(jnp.int32)
    offsets = jnp.cumsum(pcnt) - pcnt
    needed = offsets[-1] + pcnt[-1]
    slot = jnp.arange(C, dtype=jnp.int32)
    src = jnp.clip(jnp.searchsorted(offsets, slot, side="right") - 1, 0, C - 1)
    delta = slot - offsets[src]
    ok = (slot < needed) & (delta < pcnt[src])
    sidx = jnp.where(ok, jnp.clip(poff[src] + delta, 0, R - 1), R)
    sub = slab[sidx]                                   # (C, d1-d0+1)
    assign = P.assign[src].at[:, d0:d1 + 1].set(sub)
    out = P._replace(assign=assign,
                     factor=P.factor[src],
                     valid=ok,
                     orig=P.orig[src],
                     lo=P.lo[src], hi=P.hi[src])
    perm = jnp.argsort(jnp.logical_not(out.valid), stable=True)
    return type(out)(*(x[perm] for x in out))


# ---------------------------------------------------------------------------
# Host-driven executor
# ---------------------------------------------------------------------------


@dataclass
class _Frame:
    """Parked parent chunk of one ENTER_CHILD (the explicit chunk-stack)."""

    F: Any                       # parent chunk
    keys: Optional[jnp.ndarray]
    hit: jnp.ndarray
    hvals: jnp.ndarray
    rep_of_row: jnp.ndarray
    first_idx: Optional[jnp.ndarray]
    n_reps: Optional[jnp.ndarray]
    use_t1: bool
    use_t2: bool
    # evaluation-mode tier-2: per-row payload pointers of the probe hits
    poff: Optional[jnp.ndarray] = None
    plen: Optional[jnp.ndarray] = None


@dataclass
class _Span:
    """One open ENTER…FOLD bracket on the executor's explicit stack:
    the parent chunks still to run, the parked frame of the one currently
    inside the subtree, and the folded continuations collected so far."""

    enter_pc: int
    fold_pc: int
    parents: List[Any]
    next_i: int
    frame: Optional[_Frame]
    conts: List[Any]


class ScheduleExecutor:
    """Execute a :class:`Schedule` over morsel chunks (host-driven).

    An iterative interpreter over the linear op list; the state is the
    current chunk list plus an explicit stack of :class:`_Span` records
    (the parked parent chunks of open ENTER…FOLD brackets) — the
    flattened form of the old per-node recursion.

    Two orders compose here:

    * **Within an op, chunks batch.**  All chunks at an op are processed
      together, so device→host syncs are O(ops), not O(chunks): one
      planning fetch plus one batched ``valid.any()`` admission check per
      op execution, via :func:`hostsync.device_get`.
    * **Across an ENTER…FOLD span, parent chunks run sequentially.**
      Parent chunk *i*'s subtree is probed, expanded, and its results
      *inserted into the tier-2 table* before chunk *i+1* probes — the
      paper's cache[α, μ|α] reuse across morsels (Fig 10's hit rates
      come precisely from later morsels hitting earlier morsels'
      inserts; a probe-everything-then-insert pass would never hit
      within a query).

    ``mode="count"`` multiplies subtree counts into factors (tier 1 + 2);
    ``mode="evaluate"`` materializes tuples: FOLD replays representative
    row blocks through ``orig`` — drained one-shot by :meth:`evaluate`
    or streamed by :meth:`evaluate_stream` (blocks leave through a
    bounded async fetch queue as they are produced; DESIGN.md §2.8).  With ``cache_payloads`` on, evaluation
    also uses tier 2: ENTER probes the payload table, hit rows skip the
    bag entirely, and FOLD splices their cached factorized blocks back
    through the same jitted replay step while storing the miss
    representatives' fresh blocks (DESIGN.md §2.6).  Count-only tables
    are still bypassed — caching stays an optimization, never a
    correctness requirement.
    """

    def __init__(self, engine, mode: str = "count"):
        if mode not in ("count", "evaluate"):
            raise ValueError(mode)
        self.engine = engine
        self.schedule: Schedule = engine.schedule
        self.mode = mode
        self.cache = getattr(engine, "cache", None)
        self.dedup = bool(getattr(engine, "dedup", False))
        self._bracket: Dict[int, int] = {}
        open_pcs: List[int] = []
        for pc, op in enumerate(self.schedule.ops):
            if op.kind == ENTER_CHILD:
                open_pcs.append(pc)
            elif op.kind == FOLD_CHILD:
                self._bracket[open_pcs.pop()] = pc
        self._total = jnp.zeros((), jnp.int64)
        self._t1_collapsed = jnp.zeros((), jnp.int64)
        self.subtree_launches = 0
        # op-execution counters: span interiors re-run once per parent
        # morsel, so the sync budget scales with these, never with the
        # number of chunks inside one op execution
        self.op_runs = {"expand": 0, "span": 0, "fold": 0, "emit": 0}
        # EXPAND chunk launches per kernel path (the registry's choice is
        # per-depth; see kernels/registry.py and Result.expand_paths)
        self.expand_path_runs = {"pallas": 0, "xla": 0}
        self._emitted: List[Tuple[Any, Any]] = []  # (assign, valid) only
        # streaming emit (DESIGN.md §2.8): bound on in-flight device→host
        # block copies, and the fold pc whose continuations can stream
        # straight out (every op after it is EMIT — the common case of a
        # TD whose last schedule op before EMIT closes the top-level span)
        self.emit_in_flight = int(getattr(engine, "emit_in_flight", 8))
        ops = self.schedule.ops
        self._tail_fold_pc = (len(ops) - 2 if len(ops) >= 2
                              and ops[-2].kind == FOLD_CHILD else -1)
        self.emitted_blocks = 0
        self.emit_queue: Optional[AsyncFetchQueue] = None  # set by stream

    # -- public entry points -------------------------------------------
    def count(self) -> int:
        for _ in self._iter_emitted():
            pass
        return int(device_get(self._total, "emit-total"))

    def evaluate(self) -> Iterator[np.ndarray]:
        """Yields (k, n) int32 blocks of result assignments (order cols).

        One-shot drain: blocks are buffered on device until the pass
        completes, then fetched with a single batched sync (``emit-rows``).
        :meth:`evaluate_stream` is the overlapped alternative."""
        for pairs in self._iter_emitted():
            self._emitted.extend(pairs)
        if not self._emitted:
            return
        blocks = device_get(self._emitted, "emit-rows")
        for assign, valid in blocks:
            mask = np.asarray(valid)
            if mask.any():
                yield np.asarray(assign)[mask]

    def evaluate_stream(self) -> Iterator[np.ndarray]:
        """Streaming evaluation (DESIGN.md §2.8): yields the same (k, n)
        int32 blocks as :meth:`evaluate`, in the same (production) order,
        but each block's device→host copy is *issued asynchronously the
        moment the block is produced* — tail-span fold continuations and
        EMIT chunks enter a bounded :class:`~.hostsync.AsyncFetchQueue`
        whose copies overlap the next morsel's EXPAND work instead of
        draining in one blocking fetch at pass end.  Async issues ride
        ``SyncCounter.async_count``/``label_counts["emit-stream"]``; the
        blocking-sync budget stays O(ops)."""
        # kept on self so tests/benchmarks can audit the in-flight bound
        # (high_water/issued) after the stream is drained
        queue = self.emit_queue = AsyncFetchQueue(self.emit_in_flight)
        for pairs in self._iter_emitted(stream=True):
            for pair in pairs:
                for done in queue.put(pair, "emit-stream"):
                    row = self._materialize(done)
                    if row is not None:
                        yield row
            for done in queue.poll():
                row = self._materialize(done)
                if row is not None:
                    yield row
        for done in queue.drain():
            row = self._materialize(done)
            if row is not None:
                yield row

    @staticmethod
    def _materialize(pair: Tuple[Any, Any]) -> Optional[np.ndarray]:
        assign, valid = pair
        mask = np.asarray(valid)
        if not mask.any():
            return None
        return np.asarray(assign)[mask]

    def t1_rows_collapsed(self) -> int:
        return int(device_get(self._t1_collapsed, "stats-t1"))

    # -- the interpreter -----------------------------------------------
    def _iter_emitted(self, stream: bool = False
                      ) -> Iterator[List[Tuple[Any, Any]]]:
        """Run the schedule; yields lists of emitted ``(assign, valid)``
        device pairs (evaluate mode only — count mode yields nothing).

        With ``stream=True``, a top-level span whose FOLD is the last op
        before EMIT emits each parent morsel's fold continuations
        *immediately* (they are final result blocks — nothing downstream
        can change them), instead of accumulating them for the pass-end
        EMIT.  That is what lets :meth:`evaluate_stream` overlap their
        device→host copies with the next parent morsel's expansion."""
        ops = self.schedule.ops
        stack: List[_Span] = []
        chunks: List[Any] = [self.engine.initial_frontier()]
        pc = 0
        stream_tail = (stream and self.mode == "evaluate"
                       and self._tail_fold_pc >= 0)
        while pc < len(ops):
            if stack and pc == stack[-1].fold_pc:
                span = stack[-1]
                conts = self._fold_one(span.frame, chunks, ops[pc])
                if stream_tail and pc == self._tail_fold_pc and \
                        len(stack) == 1:
                    # final blocks: stream now, skip the pass-end EMIT
                    self.emitted_blocks += len(conts)
                    yield [(F.assign, F.valid) for F in conts]
                else:
                    span.conts.extend(conts)
                if span.next_i < len(span.parents):
                    F = span.parents[span.next_i]
                    span.next_i += 1
                    span.frame, R = self._enter_one(F, ops[span.enter_pc])
                    chunks = [R]
                    pc = span.enter_pc + 1
                else:
                    chunks = self._admit(span.conts, "fold-admit")
                    stack.pop()
                    pc += 1
                continue
            op = ops[pc]
            if op.kind == ENTER_CHILD:
                if not chunks:  # nothing reaches this subtree: skip span
                    pc = self._bracket[pc] + 1
                    continue
                span = _Span(enter_pc=pc, fold_pc=self._bracket[pc],
                             parents=chunks, next_i=1, frame=None,
                             conts=[])
                self.op_runs["span"] += 1
                span.frame, R = self._enter_one(chunks[0], op)
                stack.append(span)
                chunks = [R]
                pc += 1
            elif op.kind == EXPAND:
                chunks = self._op_expand(chunks, op)
                pc += 1
            else:  # EMIT
                self.op_runs["emit"] += 1
                if self.mode == "count":
                    for F in chunks:
                        self._total = self._total + jnp.sum(
                            jnp.where(F.valid, F.factor, 0))
                elif chunks:
                    # retain only what emission needs — holding whole
                    # Frontiers until the fetch would keep factor/orig/
                    # lo/hi alive for every result chunk of the query
                    self.emitted_blocks += len(chunks)
                    yield [(F.assign, F.valid) for F in chunks]
                pc += 1
        assert not stack, "unbalanced schedule"

    # -- EXPAND --------------------------------------------------------
    def _op_expand(self, chunks, op: Op):
        if not chunks:
            return []
        self.op_runs["expand"] += 1
        eng = self.engine
        d = op.d
        g_ai, rs, _ = eng.expand_plan(d)
        cap = eng.capacity
        # one planning fetch for every chunk at this op
        lo_h, hi_h, va_h = device_get(
            (jnp.stack([F.lo[:, g_ai] for F in chunks]),
             jnp.stack([F.hi[:, g_ai] for F in chunks]),
             jnp.stack([F.valid for F in chunks])), "expand-plan")
        to_run: List[Any] = []
        oversized: List[Tuple[Any, np.ndarray]] = []
        for i, F in enumerate(chunks):
            r0 = np.searchsorted(rs, lo_h[i], side="left")
            r1 = np.searchsorted(rs, hi_h[i], side="left")
            counts = np.where(va_h[i], r1 - r0, 0).astype(np.int64)
            if int(counts.sum()) <= cap:
                to_run.append(F)
            else:
                oversized.append((F, counts))
        if oversized:
            # one batched fetch for every chunk that needs morsel splitting
            hosts = device_get([F._asdict() for F, _ in oversized],
                               "expand-split")
            for (_, counts), host in zip(oversized, hosts):
                host = {k: np.asarray(v) for k, v in host.items()}
                to_run.extend(eng.split_chunk_host(host, d, counts))
        fn = eng._expand_fn(d)
        path = getattr(eng, "expand_paths", {}).get(d, "xla")
        self.expand_path_runs[path] = (
            self.expand_path_runs.get(path, 0) + len(to_run))
        return self._admit([fn(F)[0] for F in to_run], "expand-admit")

    # -- ENTER_CHILD (one parent chunk) --------------------------------
    def _enter_one(self, F, op: Op) -> Tuple[_Frame, Any]:
        C = self.engine.capacity
        cache_on = self.cache is not None and self.cache.enabled
        # evaluation mode probes tier 2 only when row-block payloads are
        # on: count tables cannot replay tuples (the PR-2 bypass)
        use_t2 = op.probe and cache_on and (
            self.mode == "count" or self.cache.config.cache_payloads)
        use_t1 = op.dedup and self.dedup
        keys = (_pack_keys(F.assign, op.adhesion, op.node)
                if (op.probe or op.dedup) else None)
        poff = plen = None
        if use_t2 and self.mode == "evaluate":
            # a payload hit means: splice the cached factorized block at
            # FOLD instead of descending into the bag for this row
            hit, poff, plen = self.cache.get(op.node).probe_payload(
                keys, F.valid)
            hvals = jnp.zeros((C,), jnp.int64)
        elif use_t2:
            hit, hvals = self.cache.get(op.node).probe(keys, F.valid)
        else:
            hit = jnp.zeros((C,), bool)
            hvals = jnp.zeros((C,), jnp.int64)
        active = F.valid & ~hit
        if use_t1:
            first_idx, rep_of_row, n_reps = _dedup(keys, active)
            self._t1_collapsed = self._t1_collapsed + (
                jnp.sum(active.astype(jnp.int64)) - n_reps)
            R = _make_rep_frontier(F, first_idx, n_reps)
        else:
            first_idx, n_reps = None, None
            rep_of_row = jnp.arange(C, dtype=jnp.int32)
            R = _identity_reps(F, active)
        self.subtree_launches += 1
        return _Frame(F=F, keys=keys, hit=hit, hvals=hvals,
                      rep_of_row=rep_of_row, first_idx=first_idx,
                      n_reps=n_reps, use_t1=use_t1, use_t2=use_t2,
                      poff=poff, plen=plen), R

    # -- FOLD_CHILD (one parent chunk's subtree exits) -----------------
    def _fold_one(self, fr: _Frame, exits: List[Any], op: Op) -> List[Any]:
        self.op_runs["fold"] += 1
        if self.mode == "evaluate":
            return self._fold_one_evaluate(fr, exits, op)
        C = self.engine.capacity
        cnt = jnp.zeros((C,), jnp.int64)
        for E in exits:
            cnt = cnt + _segment_counts(E, C)
        if fr.use_t2:
            if fr.use_t1:
                rep_keys = fr.keys[jnp.clip(fr.first_idx, 0, C - 1)]
                rep_active = jnp.arange(C) < fr.n_reps
            else:
                rep_keys = fr.keys
                rep_active = fr.F.valid & ~fr.hit
            # insert BEFORE the next parent chunk's probe (cross-morsel
            # reuse — the entire point of tier 2 within one query)
            self.cache.get(op.node).insert(rep_keys, cnt, rep_active)
            self.cache.maybe_resize(op.node)
        return [_apply_counts(fr.F, fr.hit, fr.hvals, fr.rep_of_row, cnt)]

    def _fold_one_evaluate(self, fr: _Frame, exits: List[Any],
                           op: Op) -> List[Any]:
        use_pay = fr.use_t2
        if not exits and not use_pay:
            return []
        C = self.engine.capacity
        # ONE planning fetch per fold: exit orig/valid, the parent rep map,
        # and (payload mode) the probe's hit mask + block lengths — the
        # payload plan rides the same batched device_get, O(ops) syncs
        plan = ([(E.orig, E.valid) for E in exits],
                (fr.rep_of_row, fr.F.valid & ~fr.hit))
        keys_h = None
        if use_pay:
            # with tier-1 dedup off, every parent row is its own rep —
            # the store path needs the key values to collapse duplicates,
            # so they ride the same fetch (still one sync per fold)
            extra = ((fr.hit, fr.plen) if fr.use_t1
                     else (fr.hit, fr.plen, fr.keys))
            exits_h, (ror_h, active_h), extra_h = device_get(
                plan + (extra,), "replay-plan")
            hit_h, plen_h = extra_h[0], extra_h[1]
            if not fr.use_t1:
                keys_h = extra_h[2]
        else:
            exits_h, (ror_h, active_h) = device_get(plan, "replay-plan")
        active_dev = fr.F.valid & ~fr.hit
        out: List[Any] = []
        ecnts: List[np.ndarray] = []
        for E, (eorig, evalid) in zip(exits, exits_h):
            ecnt = np.zeros(C, np.int64)
            np.add.at(ecnt, np.clip(eorig, 0, C - 1),
                      evalid.astype(np.int64))
            ecnts.append(ecnt)
            pcnt = np.where(active_h, ecnt[np.clip(ror_h, 0, C - 1)], 0)
            for mask in _pack_parent_morsels(pcnt, C):
                cont, _ = _replay_step(fr.F, active_dev & jnp.asarray(mask),
                                       fr.rep_of_row, E,
                                       d0=op.sub_first, d1=op.sub_last)
                out.append(cont)
        if use_pay:
            tbl = self.cache.get(op.node)
            if hit_h.any():
                # splice FIRST: hit parents never descended into the bag —
                # their cached factorized blocks re-expand through the
                # replay step specialized to slab sources.  The probe's
                # (poff, plen) pointers are only guaranteed until this
                # table's next insert (which may epoch-flush and reuse the
                # arena rows), so the splice must precede the insert below.
                pcnt = np.where(hit_h, plen_h, 0).astype(np.int64)
                for mask in _pack_parent_morsels(pcnt, C):
                    out.append(_splice_step(
                        fr.F, fr.hit & jnp.asarray(mask), fr.poff, fr.plen,
                        tbl.slab, d0=op.sub_first, d1=op.sub_last))
            # feed the admission throttle from the masks this fold already
            # fetched (no extra sync): probes = hit + miss parent rows
            n_hit = int(hit_h.sum())
            tbl.note_eval_probes(n_hit + int(active_h.sum()), n_hit)
            launches0 = tbl.window_launches
            if exits:
                probation = self.cache.config.payload_probation
                if tbl.store_throttled():
                    # keys don't recur on this table — stop paying the
                    # arena-write overhead.  Every Nth throttled fold
                    # still stores (probation): with nothing resident the
                    # hit rate could never recover on a workload shift.
                    tbl.payload_throttled += 1
                    if probation and tbl.payload_throttled % probation == 0:
                        self._insert_payload_blocks(fr, exits, ecnts,
                                                    active_h, keys_h, op)
                else:
                    # store the miss representatives' blocks BEFORE the
                    # next parent morsel probes (cross-morsel reuse, as in
                    # count mode); complete blocks only — a rep whose exit
                    # rows spread over several chunks would cache a
                    # partial result
                    self._insert_payload_blocks(fr, exits, ecnts,
                                                active_h, keys_h, op)
            # the sizing controller must keep running while the store
            # throttle is engaged (its whole point is handing memory back
            # on exactly these low-reuse tables) — its launch clock
            # normally advances via insert(), so tick it for insert-less
            # folds (throttled, or nothing eligible) before deciding
            if tbl.window_launches == launches0:
                tbl.window_launches = launches0 + 1
            self.cache.maybe_resize(op.node)
        return out

    def _insert_payload_blocks(self, fr: _Frame, exits: List[Any],
                               ecnts: List[np.ndarray], active_h,
                               keys_h: Optional[np.ndarray], op: Op
                               ) -> None:
        """Tier-2 payload insert at FOLD (evaluation mode): slab-write the
        representatives' row blocks and admit their keys.

        Morsel splitting partitions *rows* across exit chunks, so most
        representatives' exits live entirely in one chunk; a block is
        admitted from chunk *j* exactly when all of its rep's exit rows
        are in chunk *j* (``ecnt_j == total``).  Reps genuinely spread
        over chunks (oversized-row splits, nested-subtree morsels) would
        cache a *partial* — hence wrong — result and are skipped, which
        only costs recomputation (optionality)."""
        tbl = self.cache.get(op.node)
        C = self.engine.capacity
        total = ecnts[0] if len(ecnts) == 1 else np.sum(ecnts, axis=0)
        if fr.use_t1:
            # valid reps are exactly the rows ecnt can be nonzero at
            rep_keys = fr.keys[jnp.clip(fr.first_idx, 0, C - 1)]
            eligible = total > 0
        else:
            rep_keys = fr.keys
            eligible = (total > 0) & active_h
            if keys_h is not None:
                # dedup off: duplicate adhesion keys each carry their own
                # (identical) block, but only one copy per key can be
                # admitted — keep the first, or the rest leak arena rows
                big = np.int64(2 ** 62)
                k = np.where(eligible, keys_h, big)
                order = np.argsort(k, kind="stable")
                ks = k[order]
                isfirst = np.ones(ks.shape[0], bool)
                isfirst[1:] = ks[1:] != ks[:-1]
                isfirst &= ks != big
                first = np.zeros_like(eligible)
                first[order[isfirst]] = True
                eligible &= first
        stored = np.zeros(C, bool)
        poff_all = np.zeros(C, np.int32)
        flushes0 = tbl.payload_flushes
        for E, ecnt in zip(exits, ecnts):
            cand = eligible & (ecnt == total)
            if not cand.any():
                continue  # empty subtrees are not cached (no negatives)
            tbl.ensure_slab(op.sub_last - op.sub_first + 1)
            poff_np, admit_np = tbl.alloc_blocks(ecnt, cand)
            if tbl.payload_flushes != flushes0:
                # an epoch flush rewound the arena mid-fold: offsets
                # accumulated from earlier chunks may now be overwritten —
                # drop them from the batched admission (recompute later)
                stored[:] = False
                flushes0 = tbl.payload_flushes
            if not admit_np.any():
                continue
            tbl.slab = _store_blocks(tbl.slab, E, jnp.asarray(poff_np),
                                     jnp.asarray(admit_np),
                                     d0=op.sub_first, d1=op.sub_last)
            poff_all = np.where(admit_np, poff_np, poff_all)
            stored |= admit_np
        if stored.any():
            # one batched key admission for the whole fold (a rep is
            # complete in at most one chunk, so the admit sets are
            # disjoint); vals = block length = the exact subtree count
            # (factors are all 1 in evaluation mode), so count() can
            # reuse the entries
            lens = jnp.asarray(total)
            tbl.insert(rep_keys, lens, jnp.asarray(stored),
                       poff=jnp.asarray(poff_all),
                       plen=lens.astype(jnp.int32))
        tbl.payload_skips += int((eligible & ~stored).sum())

    # -- shared --------------------------------------------------------
    def _admit(self, out, label: str):
        """Drop empty chunks with ONE batched host sync for the whole op."""
        if not out:
            return []
        keep = device_get(jnp.stack([F.valid.any() for F in out]), label)
        return [F for F, k in zip(out, np.asarray(keep)) if k]


def _pack_parent_morsels(pcnt: np.ndarray, cap: int) -> List[np.ndarray]:
    """Greedy-pack parent rows into masks whose total replay size fits one
    chunk.  A single parent's pair count is <= the exit chunk's valid rows
    <= cap, so packing always succeeds."""
    masks: List[np.ndarray] = []
    cur = np.zeros(pcnt.shape[0], bool)
    acc = 0
    for i in np.flatnonzero(pcnt > 0):
        c = int(pcnt[i])
        if acc and acc + c > cap:
            masks.append(cur)
            cur = np.zeros(pcnt.shape[0], bool)
            acc = 0
        cur[i] = True
        acc += c
    if acc:
        masks.append(cur)
    return masks


# ---------------------------------------------------------------------------
# Static (fully-jittable) executor
# ---------------------------------------------------------------------------


def execute_static(schedule: Schedule, engine, F0, tables: Dict[int, tuple],
                   cfg, mode: str = "count"):
    """Trace-time interpreter of ``schedule``: one pure computation.

    Fixed chunk capacity (overflow is flagged, not split), tier-2 tables
    threaded functionally, LRU tick statically unrolled.  ``tables[c]`` is
    either the count-only ``(keys, vals, used, stamp, cost)`` tuple of
    ``core/cache.py`` or — payload-capable evaluation (DESIGN.md §2.8) —
    the 9-tuple extending it with ``(pay_off, pay_len, slab, bump)``: the
    §2.6 row-block region with the arena bump pointer as a traced scalar,
    so slab allocation/epoch-flush happen inside the pure computation
    (:func:`_alloc_blocks_static`).

    ``mode="count"`` returns ``(count, overflow, tables)`` —
    ``shard_map``-able as-is.  ``mode="evaluate"`` materializes: FOLD
    replays miss representatives through ``orig`` (:func:`_replay_step`),
    splices payload hits from the slab (:func:`_splice_step` — hit rows
    never descend into the bag), merges both continuations into the one
    fixed-capacity chunk (:func:`_merge_compact`; overflow flagged), and
    stores the fresh blocks; returns ``(assign, valid, count, overflow,
    replay_hits, tables)`` where ``(assign, valid)`` is the result chunk.
    Count-only tables are bypassed in evaluation mode (optionality), as in
    the host executor.  EXPAND ops route through the same
    registry-dispatched kernels as the host executor (``engine._expand_fn``
    resolves the ``expand_kernel`` knob at build time, so the choice is
    baked in before tracing).
    """
    from .cache import (_insert as cache_insert, _probe as cache_probe,
                        _probe_payload as cache_probe_payload)
    if mode not in ("count", "evaluate"):
        raise ValueError(mode)
    C = engine.capacity
    F = F0
    ov = jnp.zeros((), bool)
    stack: List[tuple] = []
    tick = 0
    total = jnp.zeros((), jnp.int64)
    n_replay = jnp.zeros((), jnp.int64)
    rows = rvalid = None
    for op in schedule.ops:
        if op.kind == EXPAND:
            F, needed = engine._expand_fn(op.d)(F)
            ov = ov | (needed > C)
        elif op.kind == ENTER_CHILD:
            keys = (_pack_keys(F.assign, op.adhesion, op.node)
                    if (op.probe or op.dedup) else None)
            tbl = tables.get(op.node)
            has_pay = tbl is not None and len(tbl) > 5
            # evaluation probes tier 2 only on payload-capable tables:
            # count-only entries cannot replay tuples (optionality)
            use_t2 = op.probe and tbl is not None and (
                mode == "count" or has_pay)
            poff = plen = None
            if use_t2 and mode == "evaluate":
                tk, tv, tu, ts, tc, tpoff, tplen, slab, bump = tbl
                tick += 1
                hit, poff, plen, ts = cache_probe_payload(
                    tk, tu, ts, tpoff, tplen, keys, F.valid,
                    jnp.int32(tick))
                hvals = jnp.zeros((C,), jnp.int64)
                n_replay = n_replay + jnp.sum(hit.astype(jnp.int64))
                tables = dict(tables)
                tables[op.node] = (tk, tv, tu, ts, tc, tpoff, tplen,
                                   slab, bump)
            elif use_t2:
                tk, tv, tu, ts, tc = tbl[:5]
                tick += 1
                hit, hvals, ts = cache_probe(tk, tv, tu, ts, keys, F.valid,
                                             jnp.int32(tick))
                tables = dict(tables)
                tables[op.node] = (tk, tv, tu, ts, tc) + tuple(tbl[5:])
            else:
                hit = jnp.zeros((C,), bool)
                hvals = jnp.zeros((C,), jnp.int64)
            active = F.valid & ~hit
            if op.dedup:
                first_idx, rep_of_row, n_reps = _dedup(keys, active)
                R = _make_rep_frontier(F, first_idx, n_reps)
            else:
                first_idx, n_reps = None, None
                rep_of_row = jnp.arange(C, dtype=jnp.int32)
                R = _identity_reps(F, active)
            stack.append((F, keys, hit, hvals, rep_of_row, first_idx,
                          n_reps, active, use_t2, poff, plen))
            F = R
        elif op.kind == FOLD_CHILD:
            (P, keys, hit, hvals, rep_of_row, first_idx, n_reps, active,
             use_t2, poff, plen) = stack.pop()
            if mode == "evaluate":
                E = F
                d0, d1 = op.sub_first, op.sub_last
                # replay the miss representatives' exits through orig
                cont, needed = _replay_step(P, active, rep_of_row, E,
                                            d0=d0, d1=d1)
                ov = ov | (needed > C)
                if use_t2:
                    (tk, tv, tu, ts, tc, tpoff, tplen, slab,
                     bump) = tables[op.node]
                    # splice payload hits BEFORE this table's insert (an
                    # epoch flush below may reuse the probed arena rows)
                    spl = _splice_step(P, hit, poff, plen, slab,
                                       d0=d0, d1=d1)
                    # the host executor pre-packs hit morsels to fit; the
                    # static path splices all hits at once, so the pair
                    # total must be overflow-checked explicitly (the
                    # splice itself clamps silently)
                    n_spl = jnp.sum(jnp.where(hit, plen, 0)
                                    .astype(jnp.int64))
                    ov = ov | (n_spl > C)
                    merged, n_tot = _merge_compact(cont, spl)
                    ov = ov | (n_tot > C)
                    F = merged
                    # store the miss reps' blocks: single exit chunk, so
                    # every rep's block is complete by construction
                    ecnt = jnp.zeros((C,), jnp.int32).at[
                        jnp.clip(E.orig, 0, C - 1)].add(
                        E.valid.astype(jnp.int32))
                    if op.dedup:
                        rep_keys = keys[jnp.clip(first_idx, 0, C - 1)]
                        eligible = (ecnt > 0) & (jnp.arange(C) < n_reps)
                    else:
                        rep_keys = keys
                        eligible = (ecnt > 0) & active
                        # duplicate adhesion keys: only the first
                        # occurrence may store (or the rest leak arena
                        # rows), mirroring the host executor's host-side
                        # collapse
                        fi, _, nr = _dedup(keys, eligible)
                        isrep = jnp.zeros((C,), jnp.int32).at[
                            jnp.clip(fi, 0, C - 1)].max(
                            (jnp.arange(C) < nr).astype(jnp.int32))
                        eligible = eligible & (isrep > 0)
                    offs, admit, bump, tplen, _fl = _alloc_blocks_static(
                        bump, tplen, ecnt, eligible,
                        cap=int(cfg.payload_rows))
                    slab = _store_blocks(slab, E, offs, admit,
                                         d0=d0, d1=d1)
                    tick += 1
                    lens = ecnt.astype(jnp.int64)
                    out = cache_insert(
                        tk, tv, tu, ts, tc, rep_keys, lens,
                        jnp.maximum(lens, 1), admit, jnp.int32(tick),
                        policy=cfg.policy, rounds=min(cfg.ways, 8),
                        pay=(tpoff, tplen, offs, ecnt))
                    tables = dict(tables)
                    tables[op.node] = out[:7] + (slab, bump)
                else:
                    F = cont
            else:
                cnt = _segment_counts(F, C)
                if use_t2:
                    if op.dedup:
                        rep_keys = keys[jnp.clip(first_idx, 0, C - 1)]
                        rep_active = jnp.arange(C) < n_reps
                    else:
                        rep_keys, rep_active = keys, active
                    tbl = tables[op.node]
                    tick += 1
                    if len(tbl) > 5:
                        # payload table in count mode: carry the metadata
                        # planes with the -1 sentinel, so an evicting
                        # count insert never leaves a stale block
                        # reachable (the §2.6 eviction-coupling rule)
                        tpoff, tplen, slab, bump = tbl[5:]
                        sent_off = jnp.zeros((C,), jnp.int32)
                        sent_len = jnp.full((C,), -1, jnp.int32)
                        out = cache_insert(
                            *tbl[:5], rep_keys, cnt, jnp.maximum(cnt, 1),
                            rep_active, jnp.int32(tick), policy=cfg.policy,
                            rounds=min(cfg.ways, 8),
                            pay=(tpoff, tplen, sent_off, sent_len))
                        new_tbl = out[:7] + (slab, bump)
                    else:
                        out = cache_insert(*tbl, rep_keys, cnt,
                                           jnp.maximum(cnt, 1), rep_active,
                                           jnp.int32(tick),
                                           policy=cfg.policy,
                                           rounds=min(cfg.ways, 8))
                        new_tbl = out[:5]
                    tables = dict(tables)
                    tables[op.node] = new_tbl
                F = _apply_counts(P, hit, hvals, rep_of_row, cnt)
        else:  # EMIT
            if mode == "count":
                total = jnp.sum(jnp.where(F.valid, F.factor, 0))
            else:
                rows, rvalid = F.assign, F.valid
                total = jnp.sum(F.valid.astype(jnp.int64))
    if mode == "count":
        return total, ov, tables
    return rows, rvalid, total, ov, n_replay, tables

"""The paper's primary contribution: flexible caching in trie joins (CLFTJ).

Layers:
  * planning  — cq / gaifman / td / separators / decompose (paper §2, §4)
  * reference — trie / lftj_ref / clftj_ref / yannakakis (paper Figs 1-2, §5.1)
  * engine    — frontier / cached_frontier (TPU-native vectorized CLFTJ)
  * facade    — engine.count / engine.evaluate / engine.plan_query
"""
from .cq import (CQ, Atom, bowtie_query, cq, path_query, cycle_query,
                 clique_query, lollipop_query, random_graph_query,
                 star_query, two_relation_cycle_query)
from .db import Counters, Database, graph_db
from .td import TreeDecomposition, singleton_td
from .decompose import (choose_plan, enumerate_tds, generic_decompose,
                        DBStats)
from .clftj_ref import CLFTJ, CachePolicy, Plan
from .lftj_ref import LFTJ, lftj_count, lftj_evaluate
from .clftj_ref import clftj_count, clftj_evaluate
from .yannakakis import YTD, ytd_count, ytd_evaluate
from .cache import CacheConfig, CacheManager, DeviceCache
from .hostsync import (AsyncFetch, AsyncFetchQueue, SyncCounter,
                       device_get_async)
from .schedule import Op, Schedule, ScheduleExecutor, lower
from .frontier import JaxTrieJoin, jax_lftj_count, jax_lftj_evaluate
from .cached_frontier import (JaxCachedTrieJoin, jax_clftj_count,
                              jax_clftj_evaluate)
from . import engine

"""CLFTJ — the paper's Figure 2 (CachedTJCount) plus evaluation mode.

Faithful host implementation of the cached trie join: an ordered TD strongly
compatible with the variable order defines, per non-root bag ``v``, an
adhesion key ``μ|α``; entering ``v`` probes ``cache[v, μ|α]`` and a hit skips
the whole subtree interval, multiplying the carried factor; a miss proceeds
as vanilla LFTJ while maintaining ``intrmd(v)`` (children products), and may
insert on exit subject to a pluggable admission policy (paper §3.4).

Evaluation mode (paper §3.4 discussion) records subtree assignments (the
factorized intermediate) and replays them on a hit.
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .cq import CQ
from .db import Counters, Database
from .td import TreeDecomposition
from .trie import AtomTrie, leapfrog_intersection


@dataclass
class CachePolicy:
    """Paper §3.4 / §5.3.3 cache controls.

    * ``support_threshold``: admit (v, key) only once it has been *probed* at
      least this many times (1 = cache every intermediate result, the paper's
      default configuration).
    * ``capacity``: max resident entries (Fig 10's dynamic cache size); when
      full, ``evict`` decides: "none" stops admitting, "lru" evicts the
      least-recently-used entry, "cost" evicts the cheapest resident entry
      — but only when the candidate is at least as valuable (its count, a
      proxy for the recomputation a future hit avoids).
    * ``enabled_nodes``: restrict caching to specific TD nodes (Fig 11's
      cache-structure experiments); None = all non-root nodes.
    """

    support_threshold: int = 1
    capacity: Optional[int] = None
    evict: str = "none"  # "none" | "lru" | "cost"
    enabled_nodes: Optional[frozenset] = None

    def node_enabled(self, v: int) -> bool:
        return self.enabled_nodes is None or v in self.enabled_nodes

    @classmethod
    def from_cache_config(cls, cfg) -> "CachePolicy":
        """Host-engine analogue of a device :class:`~.cache.CacheConfig`:
        bounded table, eviction flavor matched to the device policy."""
        cap = cfg.budget if cfg.budget is not None else cfg.slots
        return cls(capacity=int(cap),
                   evict="cost" if cfg.policy == "costaware" else "lru",
                   enabled_nodes=cfg.enabled_nodes)


class Cache:
    def __init__(self, policy: CachePolicy, counters: Counters):
        self.policy = policy
        self.counters = counters
        self.store: "OrderedDict[Tuple[int, Tuple[int, ...]], object]" = OrderedDict()
        self.support: Dict[Tuple[int, Tuple[int, ...]], int] = {}
        # "cost" eviction: lazy min-heap of (cost, key); stale entries
        # (evicted or re-inserted at a new cost) are dropped on pop
        self._cost_heap: List[Tuple[int, Tuple[int, Tuple[int, ...]]]] = []

    def probe(self, v: int, key: Tuple[int, ...]):
        self.counters.count_hash()
        k = (v, key)
        self.support[k] = self.support.get(k, 0) + 1
        if k in self.store:
            self.counters.cache_hits += 1
            if self.policy.evict == "lru":
                self.store.move_to_end(k)
            return self.store[k]
        self.counters.cache_misses += 1
        return None

    @staticmethod
    def _cost(value) -> int:
        """Recomputation-cost proxy: the count (or the number of recorded
        subtree assignments in evaluation mode)."""
        n = len(value) if isinstance(value, list) else int(value)
        return max(1, n)

    def _cheapest(self) -> Optional[Tuple[int, Tuple[int, Tuple[int, ...]]]]:
        """Peek the valid minimum-cost resident entry (amortized O(log n))."""
        while self._cost_heap:
            c, k = self._cost_heap[0]
            if k in self.store and self._cost(self.store[k]) == c:
                return c, k
            heapq.heappop(self._cost_heap)
        return None

    def put(self, v: int, key: Tuple[int, ...], value) -> None:
        if not self.policy.node_enabled(v):
            self.counters.cache_skipped += 1
            return
        k = (v, key)
        if self.support.get(k, 0) < self.policy.support_threshold:
            self.counters.cache_skipped += 1
            return
        if self.policy.capacity is not None and len(self.store) >= self.policy.capacity:
            if self.policy.capacity == 0:
                self.counters.cache_skipped += 1
                return
            if self.policy.evict == "lru":
                self.store.popitem(last=False)
            elif self.policy.evict == "cost":
                cheapest = self._cheapest()
                if cheapest is None or self._cost(value) < cheapest[0]:
                    self.counters.cache_skipped += 1
                    return
                heapq.heappop(self._cost_heap)
                del self.store[cheapest[1]]
            else:
                self.counters.cache_skipped += 1
                return
        self.counters.cache_inserts += 1
        self.counters.count_hash()
        self.store[k] = value
        if self.policy.evict == "cost":
            heapq.heappush(self._cost_heap, (self._cost(value), k))

    def __len__(self) -> int:
        return len(self.store)


@dataclass
class Plan:
    """Precomputed TD/order correspondence used by CLFTJ."""

    td: TreeDecomposition
    order: Tuple[str, ...]
    owner_of: List[int]          # depth -> owning node
    first_d: Dict[int, int]      # node -> first owned depth
    last_d: Dict[int, int]       # node -> last owned depth
    subtree_last: Dict[int, int]  # node -> last depth owned within t|v
    adhesion_idx: Dict[int, Tuple[int, ...]]  # node -> order positions of α

    @staticmethod
    def build(td: TreeDecomposition, order: Sequence[str]) -> "Plan":
        order = tuple(order)
        if not td.is_strongly_compatible(order):
            raise ValueError("TD must be strongly compatible with the order")
        owner = td.owners()
        pos = {x: i for i, x in enumerate(order)}
        owner_of = [owner[x] for x in order]
        first_d: Dict[int, int] = {}
        last_d: Dict[int, int] = {}
        for d, v in enumerate(owner_of):
            first_d.setdefault(v, d)
            last_d[v] = d
        for v in range(td.num_nodes):
            if v not in first_d:
                if td.parent[v] >= 0:
                    raise ValueError(
                        f"non-root bag {v} owns no variable; run "
                        "eliminate_redundant_bags() first")
                continue
            # owned depths must be contiguous (strong compatibility)
            owned = [d for d, o in enumerate(owner_of) if o == v]
            assert owned == list(range(first_d[v], last_d[v] + 1))
        subtree_last: Dict[int, int] = {}
        for v in reversed(td.preorder()):
            sl = last_d.get(v, -1)
            for c in td.children[v]:
                sl = max(sl, subtree_last[c])
            subtree_last[v] = sl
        adhesion_idx = {
            v: tuple(sorted(pos[x] for x in td.adhesion(v)))
            for v in range(td.num_nodes)}
        return Plan(td, order, owner_of, first_d, last_d, subtree_last,
                    adhesion_idx)


class CLFTJ:
    """Cached trie join (paper Fig 2).  ``mode``: "count" or "evaluate"."""

    def __init__(self, q: CQ, td: TreeDecomposition, order: Sequence[str],
                 db: Database, policy: Optional[CachePolicy] = None,
                 counters: Optional[Counters] = None):
        self.q = q
        self.plan = Plan.build(td, order)
        self.order = tuple(order)
        self.db = db
        self.counters = counters if counters is not None else Counters()
        self.policy = policy or CachePolicy()
        self.cache = Cache(self.policy, self.counters)
        self.tries = [AtomTrie.build(db, a.relation, a.vars, self.order)
                      for a in q.atoms]
        self.at_depth: List[List[Tuple[int, int]]] = []
        for x in self.order:
            parts = []
            for ai, at in enumerate(self.tries):
                if x in at.var_order:
                    parts.append((ai, at.level_of(x)))
            self.at_depth.append(parts)

    # ------------------------------------------------------------------
    def count(self) -> int:
        n = len(self.order)
        plan, td = self.plan, self.plan.td
        mu: List[int] = [0] * n
        ranges: List[Dict[int, Tuple[int, int]]] = [dict() for _ in range(n + 2)]
        ranges[0] = {ai: at.trie.full_range()
                     for ai, at in enumerate(self.tries)}
        intrmd: List[int] = [0] * td.num_nodes
        total = 0

        def rjoin(d: int, f: int) -> None:
            nonlocal total
            if d == n:
                total += f
                self.counters.tuples_emitted += 1
                return
            v = plan.owner_of[d]
            entering = d == 0 or plan.owner_of[d - 1] != v
            key: Optional[Tuple[int, ...]] = None
            if entering:
                intrmd[v] = 0
                if d > 0:  # paper lines 6-12
                    key = tuple(mu[i] for i in plan.adhesion_idx[v])
                    cached = self.cache.probe(v, key)
                    if cached is not None:
                        l = plan.subtree_last[v]
                        ranges[l + 1] = ranges[d]
                        rjoin(l + 1, f * cached)
                        intrmd[v] = cached
                        return
            parts = self.at_depth[d]
            iters = [(self.tries[ai].trie, lvl, *ranges[d][ai])
                     for ai, lvl in parts]
            children = td.children[v]
            for a, sub in leapfrog_intersection(iters, self.counters):
                mu[d] = a
                nxt = dict(ranges[d])
                for (ai, _lvl), (s, e) in zip(parts, sub):
                    nxt[ai] = (s, e)
                ranges[d + 1] = nxt
                rjoin(d + 1, f)
                if d == plan.last_d[v]:  # paper lines 16-18
                    prod = 1
                    for c in children:
                        prod *= intrmd[c]
                    intrmd[v] += prod
            if entering and d > 0:  # paper lines 20-22
                self.cache.put(v, key, intrmd[v])

        rjoin(0, 1)
        return total

    # ------------------------------------------------------------------
    def evaluate(self) -> Iterator[Tuple[int, ...]]:
        """Evaluation mode: caches store subtree assignment lists (the
        factorized intermediates of paper §3.4) and hits replay them."""
        n = len(self.order)
        plan, td = self.plan, self.plan.td
        mu: List[int] = [0] * n
        ranges: List[Dict[int, Tuple[int, int]]] = [dict() for _ in range(n + 2)]
        ranges[0] = {ai: at.trie.full_range()
                     for ai, at in enumerate(self.tries)}
        # active recorders: node -> list being filled (keyed per entry)
        recorders: Dict[int, List[Tuple[int, ...]]] = {}

        def rjoin(d: int) -> Iterator[Tuple[int, ...]]:
            if d == n:
                self.counters.tuples_emitted += 1
                yield tuple(mu)
                return
            v = plan.owner_of[d]
            entering = d == 0 or plan.owner_of[d - 1] != v
            key: Optional[Tuple[int, ...]] = None
            recording = False
            if entering and d > 0:
                key = tuple(mu[i] for i in plan.adhesion_idx[v])
                cached = self.cache.probe(v, key)
                l = plan.subtree_last[v]
                if cached is not None:
                    ranges[l + 1] = ranges[d]
                    for sub_assign in cached:
                        mu[d:l + 1] = list(sub_assign)
                        # ancestors recording an interval that ends exactly
                        # where this skip ends would miss their capture point
                        # (it sits inside the skipped region) — capture here.
                        for w, buf in recorders.items():
                            if plan.subtree_last[w] == l:
                                buf.append(tuple(mu[plan.first_d[w]:l + 1]))
                        yield from rjoin(l + 1)
                    return
                if self.policy.node_enabled(v) and v not in recorders:
                    recorders[v] = []
                    recording = True

            # boundary crossing: record arrivals for any recorder whose
            # subtree interval ends at d-1
            parts = self.at_depth[d]
            iters = [(self.tries[ai].trie, lvl, *ranges[d][ai])
                     for ai, lvl in parts]
            for a, sub in leapfrog_intersection(iters, self.counters):
                mu[d] = a
                nxt = dict(ranges[d])
                for (ai, _lvl), (s, e) in zip(parts, sub):
                    nxt[ai] = (s, e)
                ranges[d + 1] = nxt
                if d + 1 == n or plan.owner_of[d + 1] != v:
                    # leaving v's own vars: capture for recorders closing here
                    for w, buf in recorders.items():
                        if plan.subtree_last[w] == d:
                            buf.append(tuple(mu[plan.first_d[w]:d + 1]))
                yield from rjoin(d + 1)
            if recording:
                buf = recorders.pop(v)
                self.cache.put(v, key, buf)

        yield from rjoin(0)


def clftj_count(q: CQ, td: TreeDecomposition, order: Sequence[str],
                db: Database, policy: Optional[CachePolicy] = None,
                counters: Optional[Counters] = None) -> int:
    return CLFTJ(q, td, order, db, policy, counters).count()


def clftj_evaluate(q: CQ, td: TreeDecomposition, order: Sequence[str],
                   db: Database, policy: Optional[CachePolicy] = None,
                   counters: Optional[Counters] = None) -> List[Tuple[int, ...]]:
    return list(CLFTJ(q, td, order, db, policy, counters).evaluate())

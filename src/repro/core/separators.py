"""Constrained separator enumeration (paper §4.2, Lemma 4.3 / Theorem 4.4).

Given an undirected graph ``g`` and a node set ``C``, a *C-constrained
separating set* is a set S of nodes such that

  (1) g - S is disconnected, and
  (2) at least one connected component of g - S is disjoint from C.

We enumerate these by **increasing size, without repetition, with polynomial
delay**, via Lawler–Murty's procedure over a minimum-solution oracle that
supports membership constraints (forced-in set I, excluded set X).  The oracle
reduces to minimum vertex s-t cut via the standard node-splitting max-flow
construction; source-side nodes (the C nodes) stay cuttable, which the paper
needs because S may intersect C.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Dict, FrozenSet, Iterator, List, Optional, Set, Tuple

from .gaifman import Graph, connected_components, is_separating_set, remove_nodes

INF = 10 ** 9


# ---------------------------------------------------------------------------
# Max-flow (Edmonds–Karp) on a tiny node-split network
# ---------------------------------------------------------------------------

class _FlowNet:
    """Dict-based capacities; adequate for query graphs (<= ~dozens of nodes)."""

    def __init__(self) -> None:
        self.cap: Dict[Tuple[str, str], int] = {}
        self.adj: Dict[str, List[str]] = {}

    def add_edge(self, u: str, v: str, c: int) -> None:
        if (u, v) not in self.cap:
            self.adj.setdefault(u, []).append(v)
            self.adj.setdefault(v, []).append(u)
            self.cap[(u, v)] = 0
            self.cap.setdefault((v, u), 0)
        self.cap[(u, v)] += c

    def max_flow(self, s: str, t: str) -> int:
        flow = 0
        while True:
            # BFS for an augmenting path
            parent: Dict[str, str] = {s: s}
            q = deque([s])
            while q and t not in parent:
                u = q.popleft()
                for v in self.adj.get(u, ()):
                    if v not in parent and self.cap[(u, v)] > 0:
                        parent[v] = u
                        q.append(v)
            if t not in parent:
                return flow
            # find bottleneck
            b = INF
            v = t
            while v != s:
                u = parent[v]
                b = min(b, self.cap[(u, v)])
                v = u
            v = t
            while v != s:
                u = parent[v]
                self.cap[(u, v)] -= b
                self.cap[(v, u)] += b
                v = u
            flow += b

    def source_side(self, s: str) -> Set[str]:
        """Nodes reachable from s in the residual network (after max_flow)."""
        seen = {s}
        q = deque([s])
        while q:
            u = q.popleft()
            for v in self.adj.get(u, ()):
                if v not in seen and self.cap[(u, v)] > 0:
                    seen.add(v)
                    q.append(v)
        return seen


def _min_vertex_cut(g: Graph, sources: Set[str], sink: str,
                    removable_penalty: Dict[str, int]) -> Optional[Set[str]]:
    """Minimum-cardinality node set disjoint from {sink} whose removal
    disconnects every source from ``sink``.  ``removable_penalty[v]`` is the
    cost of cutting v (INF = not removable).  Source nodes ARE removable when
    their penalty is finite.  Returns None if no finite cut exists.
    """
    if sink in sources:
        return None
    net = _FlowNet()
    SRC = "#src"
    for v in g:
        c = INF if v == sink else removable_penalty.get(v, 1)
        net.add_edge(f"{v}.i", f"{v}.o", c)
    for u in g:
        for w in g[u]:
            net.add_edge(f"{u}.o", f"{w}.i", INF)
    for c_node in sources:
        net.add_edge(SRC, f"{c_node}.i", INF)  # entering at .i keeps c cuttable
    val = net.max_flow(SRC, f"{sink}.i")
    if val >= INF:
        return None
    side = net.source_side(SRC)
    cut = {v for v in g
           if f"{v}.i" in side and f"{v}.o" not in side}
    assert len(cut) == val, (cut, val)
    return cut


# ---------------------------------------------------------------------------
# The constrained-minimum oracle (Lemma 4.3's optimization problem)
# ---------------------------------------------------------------------------

def _is_valid(g: Graph, C: Set[str], S: Set[str]) -> bool:
    if not S <= set(g):
        return False
    comps = connected_components(remove_nodes(g, S))
    if len(comps) < 2:
        return False
    return any(not (comp & C) for comp in comps)


def min_constrained_separator(
        g: Graph, C: Set[str],
        forced: FrozenSet[str] = frozenset(),
        excluded: FrozenSet[str] = frozenset(),
) -> Optional[FrozenSet[str]]:
    """Minimum C-constrained separating set S with forced ⊆ S, S ∩ excluded = ∅.

    Two exhaustive cases (see DESIGN.md §2 / paper §4.2):
      (a) some c ∈ C survives (c ∉ S): S must isolate a C-free component, so
          for a witness node t ∉ C ∪ S, S separates t from every surviving
          C node — a min vertex cut with C as (cuttable) sources, t as sink.
          To guarantee the *extracted* min cut is itself valid, we pin one
          candidate survivor c (uncuttable) per run; any valid solution with
          surviving c is feasible for its (t, c) run, and every cut the run
          extracts is valid (c survives ⇒ disconnection + C-free component).
      (b) C ⊆ S: condition (2) is vacuous; S must merely disconnect g, so we
          force C into S and take a min s-t vertex cut over witness pairs,
          with both witnesses pinned uncuttable.
    Together the considered candidates include a true minimum, and all
    candidates are verified, so the returned set is an exact minimum.
    """
    V = set(g)
    if forced & excluded or not forced <= V:
        return None
    best: Optional[Set[str]] = None

    def consider(S: Optional[Set[str]]) -> None:
        nonlocal best
        if S is None:
            return
        if not (forced <= S) or (S & excluded):
            return
        if _is_valid(g, C, S) and (best is None or len(S) < len(best)):
            best = S

    g1 = remove_nodes(g, forced)  # forced nodes are in S by fiat
    penalty = {v: (INF if v in excluded else 1) for v in g1}

    # Case (a): witness t outside C ∪ S; pinned survivor c ∈ C.
    sources_a = (C - forced) & set(g1)
    for t in sorted(set(g1) - C):
        for c in sorted(sources_a):
            pen = dict(penalty)
            pen[c] = INF  # c must survive
            cut = _min_vertex_cut(g1, sources_a, t, pen)
            if cut is not None:
                consider(cut | set(forced))

    # Case (b): C ⊆ S (also covers C = ∅).
    forced_b = set(forced) | (C & V)
    if not (forced_b & excluded):
        g2 = remove_nodes(g, forced_b)
        penalty2 = {v: (INF if v in excluded else 1) for v in g2}
        nodes2 = sorted(g2)
        for i, s in enumerate(nodes2):
            for t in nodes2[i + 1:]:
                if s in g2[t]:
                    continue  # adjacent ⇒ no vertex cut separates them
                pen = dict(penalty2)
                pen[s] = INF  # both witnesses must survive
                cut = _min_vertex_cut(g2, {s}, t, pen)
                if cut is not None:
                    consider(cut | forced_b)

    return frozenset(best) if best is not None else None


# ---------------------------------------------------------------------------
# Lawler–Murty ranked enumeration (Theorem 4.4)
# ---------------------------------------------------------------------------

def enumerate_constrained_separators(
        g: Graph, C: Set[str],
        max_size: Optional[int] = None,
        max_results: Optional[int] = None,
) -> Iterator[FrozenSet[str]]:
    """Yield all C-constrained separating sets by increasing size.

    Lawler–Murty: pop the globally smallest solution S of an open subproblem
    (I, X); branch into child subproblems that partition "solutions ≠ S":
      * for v_i ∈ S \\ I (ordered): solutions containing v_1..v_{i-1}, not v_i;
      * strict supersets of S: for candidate u_j ∉ S ∪ X (ordered): solutions
        ⊇ S ∪ {u_j} excluding u_1..u_{j-1}.
    Disjointness of the child spaces gives no-repetition; the heap gives
    increasing size; each branch costs one polynomial oracle call ⇒
    polynomial delay.
    """
    first = min_constrained_separator(g, C)
    if first is None:
        return
    counter = itertools.count()  # heap tie-break
    heap: List[Tuple[int, int, FrozenSet[str], FrozenSet[str], FrozenSet[str]]] = []
    heapq.heappush(heap, (len(first), next(counter), first,
                          frozenset(), frozenset()))
    emitted: Set[FrozenSet[str]] = set()
    n_out = 0
    while heap:
        size, _, S, I, X = heapq.heappop(heap)
        if max_size is not None and size > max_size:
            return
        assert S not in emitted, "Lawler–Murty spaces must be disjoint"
        emitted.add(S)
        yield S
        n_out += 1
        if max_results is not None and n_out >= max_results:
            return
        # children: exclude one element of S \ I at a time
        delta = sorted(S - I)
        for i, v in enumerate(delta):
            I_i = I | frozenset(delta[:i])
            X_i = X | frozenset([v])
            S_i = min_constrained_separator(g, C, I_i, X_i)
            if S_i is not None:
                heapq.heappush(heap, (len(S_i), next(counter), S_i, I_i, X_i))
        # children: strict supersets of S
        cands = sorted(set(g) - S - X)
        for j, u in enumerate(cands):
            I_j = S | frozenset([u])
            X_j = X | frozenset(cands[:j])
            S_j = min_constrained_separator(g, C, I_j, X_j)
            if S_j is not None:
                heapq.heappush(heap, (len(S_j), next(counter), S_j, I_j, X_j))


def brute_force_constrained_separators(
        g: Graph, C: Set[str], max_size: Optional[int] = None,
) -> List[FrozenSet[str]]:
    """Exponential oracle for tests: all valid S, sorted by (size, lex)."""
    V = sorted(g)
    out = []
    bound = len(V) if max_size is None else max_size
    for k in range(0, bound + 1):
        for sub in itertools.combinations(V, k):
            S = set(sub)
            if _is_valid(g, C, S):
                out.append(frozenset(S))
    return sorted(out, key=lambda s: (len(s), tuple(sorted(s))))

"""YTD — Yannakakis's algorithm over a tree decomposition (paper §5.1).

Per the paper's implementation notes: each bag is materialized with a
worst-case-optimal join (we reuse our LFTJ as the GenericJoin stand-in,
including atoms *touching* the bag and projecting — the EmptyHeaded-style
edge-cover handling); counting aggregates bottom-up per adhesion key instead
of storing full intermediates; evaluation semijoin-reduces then enumerates.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from .cq import CQ, Atom
from .db import Counters, Database
from .lftj_ref import LFTJ
from .td import TreeDecomposition


class YTD:
    def __init__(self, q: CQ, td: TreeDecomposition, db: Database,
                 counters: Optional[Counters] = None):
        self.q = q
        self.td = td
        self.db = db
        self.counters = counters if counters is not None else Counters()
        # deterministic global variable order for tuple layouts
        self.var_pos = {x: i for i, x in enumerate(q.variables)}

    # -- bag materialization -------------------------------------------------
    def _bag_vars(self, v: int) -> Tuple[str, ...]:
        return tuple(sorted(self.td.bags[v], key=self.var_pos.get))

    def _materialize_bag(self, v: int) -> Tuple[Tuple[str, ...], Set[Tuple[int, ...]]]:
        """R_v = π_{χ(v)}( join of atoms touching χ(v) ), via LFTJ."""
        bag = set(self.td.bags[v])
        atoms = [a for a in self.q.atoms if set(a.vars) & bag]
        assert atoms, f"bag {v} touches no atom"
        sub = CQ(tuple(atoms))
        sub_vars = list(sub.variables)
        # order: bag vars first (so projection is a prefix — cheap dedupe)
        order = sorted(sub_vars, key=lambda x: (x not in bag, self.var_pos[x]))
        bag_vars = tuple(x for x in order if x in bag)
        k = len(bag_vars)
        out: Set[Tuple[int, ...]] = set()
        eng = LFTJ(sub, order, self.db, self.counters)
        for tup in eng.evaluate():
            out.add(tup[:k])
        self.counters.intermediate_tuples += len(out)
        return bag_vars, out

    # -- counting (bottom-up adhesion-keyed aggregation) ----------------------
    def count(self) -> int:
        td = self.td
        bag_rel: Dict[int, Tuple[Tuple[str, ...], Set[Tuple[int, ...]]]] = {
            v: self._materialize_bag(v) for v in range(td.num_nodes)}
        # M[v]: adhesion key -> number of subtree extensions
        M: Dict[int, Dict[Tuple[int, ...], int]] = {}
        for v in reversed(td.preorder()):
            vars_v, rel_v = bag_rel[v]
            pos_v = {x: i for i, x in enumerate(vars_v)}
            child_keys = [
                (c, tuple(pos_v[x] for x in sorted(td.adhesion(c),
                                                   key=self.var_pos.get)))
                for c in td.children[v]]
            adh = tuple(pos_v[x] for x in sorted(td.adhesion(v),
                                                 key=self.var_pos.get))
            acc: Dict[Tuple[int, ...], int] = defaultdict(int)
            for t in rel_v:
                prod = 1
                for c, idx in child_keys:
                    self.counters.count_hash()
                    prod *= M[c].get(tuple(t[i] for i in idx), 0)
                    if prod == 0:
                        break
                if prod:
                    acc[tuple(t[i] for i in adh)] += prod
            M[v] = dict(acc)
        root_total = sum(M[td.root].values())
        return root_total

    # -- evaluation (semijoin reduce + enumerate) -----------------------------
    def evaluate(self) -> List[Tuple[int, ...]]:
        td = self.td
        bag_rel = {v: self._materialize_bag(v) for v in range(td.num_nodes)}

        def project(t, idx):
            return tuple(t[i] for i in idx)

        # bottom-up semijoin: keep parent tuples with a match in every child
        order_nodes = td.preorder()
        for v in reversed(order_nodes):
            vars_v, rel_v = bag_rel[v]
            pos_v = {x: i for i, x in enumerate(vars_v)}
            for c in td.children[v]:
                vars_c, rel_c = bag_rel[c]
                pos_c = {x: i for i, x in enumerate(vars_c)}
                shared = sorted(td.adhesion(c), key=self.var_pos.get)
                idx_v = tuple(pos_v[x] for x in shared)
                idx_c = tuple(pos_c[x] for x in shared)
                keys = {project(t, idx_c) for t in rel_c}
                self.counters.count_hash(len(rel_v))
                rel_v = {t for t in rel_v if project(t, idx_v) in keys}
            bag_rel[v] = (vars_v, rel_v)
        # top-down semijoin
        for v in order_nodes:
            vars_v, rel_v = bag_rel[v]
            pos_v = {x: i for i, x in enumerate(vars_v)}
            for c in td.children[v]:
                vars_c, rel_c = bag_rel[c]
                pos_c = {x: i for i, x in enumerate(vars_c)}
                shared = sorted(td.adhesion(c), key=self.var_pos.get)
                idx_v = tuple(pos_v[x] for x in shared)
                idx_c = tuple(pos_c[x] for x in shared)
                keys = {project(t, idx_v) for t in rel_v}
                self.counters.count_hash(len(rel_c))
                bag_rel[c] = (vars_c,
                              {t for t in rel_c if project(t, idx_c) in keys})
        # index children by adhesion key
        child_index: Dict[int, Dict[Tuple[int, ...], List[Tuple[int, ...]]]] = {}
        for v in order_nodes:
            vars_v, rel_v = bag_rel[v]
            pos_v = {x: i for i, x in enumerate(vars_v)}
            if td.parent[v] >= 0:
                shared = sorted(td.adhesion(v), key=self.var_pos.get)
                idx = tuple(pos_v[x] for x in shared)
                index: Dict[Tuple[int, ...], List[Tuple[int, ...]]] = defaultdict(list)
                for t in rel_v:
                    index[project(t, idx)].append(t)
                child_index[v] = dict(index)

        # enumerate full assignments by walking bags in preorder
        all_vars = self.q.variables
        n = len(all_vars)
        results: List[Tuple[int, ...]] = []
        mu: Dict[str, int] = {}

        def rec(i: int) -> None:
            if i == len(order_nodes):
                results.append(tuple(mu[x] for x in all_vars))
                self.counters.tuples_emitted += 1
                return
            v = order_nodes[i]
            vars_v, rel_v = bag_rel[v]
            if td.parent[v] >= 0:
                shared = sorted(td.adhesion(v), key=self.var_pos.get)
                key = tuple(mu[x] for x in shared)
                self.counters.count_hash()
                cand = child_index[v].get(key, [])
            else:
                cand = list(rel_v)
            for t in cand:
                consistent = True
                added: List[str] = []
                for x, val in zip(vars_v, t):
                    if x in mu:
                        if mu[x] != val:
                            consistent = False
                            break
                    else:
                        mu[x] = val
                        added.append(x)
                if consistent:
                    rec(i + 1)
                for x in added:
                    del mu[x]

        rec(0)
        return results


def ytd_count(q: CQ, td: TreeDecomposition, db: Database,
              counters: Optional[Counters] = None) -> int:
    return YTD(q, td, db, counters).count()


def ytd_evaluate(q: CQ, td: TreeDecomposition, db: Database,
                 counters: Optional[Counters] = None) -> List[Tuple[int, ...]]:
    return YTD(q, td, db, counters).evaluate()

"""Public join-engine API: plan + execute CLFTJ/LFTJ/YTD on any backend.

    from repro.core import engine
    res = engine.count(q, db)                     # plans a TD, runs JAX CLFTJ
    res = engine.count(q, db, algorithm="lftj")   # vanilla trie join
    res = engine.count(q, db, backend="ref")      # paper-faithful host engines
    res = engine.evaluate(q, db, backend="jax")   # materialized tuples on JAX

Timing discipline: ``Result`` separates ``plan_s`` (TD/order planning),
``compile_s`` (jit trace+lower+XLA compile, measured via jax.monitoring
events), and ``exec_s`` (the remainder) — so benchmark numbers stop
charging jit warm-up to the algorithm.  ``wall_s`` stays the end-to-end
total for backwards compatibility.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .cache import CacheConfig
from .cq import CQ
from .clftj_ref import CLFTJ, CachePolicy
from .cached_frontier import JaxCachedTrieJoin
from .db import Counters, Database
from .decompose import choose_plan
from .frontier import JaxTrieJoin
from .lftj_ref import LFTJ
from .td import TreeDecomposition
from .yannakakis import YTD


@dataclass
class Result:
    count: Optional[int]
    tuples: Optional[np.ndarray]
    algorithm: str
    backend: str
    order: Tuple[str, ...]
    td: Optional[TreeDecomposition]
    counters: Dict[str, int] = field(default_factory=dict)
    wall_s: float = 0.0     # end-to-end (= plan_s + compile_s + exec_s)
    plan_s: float = 0.0     # TD enumeration + order selection
    compile_s: float = 0.0  # jit trace / lowering / XLA backend compile
    exec_s: float = 0.0     # actual engine execution

    @property
    def tier2_replay_hits(self) -> int:
        """Evaluation-mode tier-2 hits served by row-block replay: parent
        rows whose bag subtree was spliced from the payload slab instead
        of re-expanded (each expands to its block's rows); 0 unless the
        JAX engine ran with ``cache_payloads=True``."""
        return int(self.counters.get("tier2_replay_hits", 0))

    @property
    def expand_paths(self) -> Dict[str, int]:
        """EXPAND chunk launches per kernel path (``kernels/registry.py``
        dispatch): ``{"pallas": n, "xla": n}`` — which implementation the
        ``expand_kernel`` knob actually resolved to; empty for non-JAX
        backends."""
        return {k[len("expand_calls_"):]: int(v)
                for k, v in self.counters.items()
                if k.startswith("expand_calls_")}

    @property
    def plan_cache_hit(self) -> bool:
        """True when this query was answered by a plan-cached engine (the
        serving layer's compile-once path, ``repro/serve``): planning, trie
        construction and jit warm-up were all skipped, and the engine's
        tier-2 tables were already warm from earlier queries.  Always False
        for the one-shot ``count``/``evaluate`` facade calls."""
        return bool(self.counters.get("plan_cache_hit", 0))


# -- compile-time accounting (jax.monitoring duration events) --------------

_compile_lock = threading.Lock()
_compile_accs: List[List[float]] = []
_listener_installed = False


def _install_listener() -> None:
    global _listener_installed
    if _listener_installed:
        return
    try:
        import jax.monitoring

        def _on_duration(name: str, secs: float, **_kw) -> None:
            if name.startswith("/jax/core/compile"):
                with _compile_lock:
                    for acc in _compile_accs:
                        acc[0] += secs

        jax.monitoring.register_event_duration_secs_listener(_on_duration)
        _listener_installed = True
    except Exception:  # pragma: no cover - monitoring API unavailable
        _listener_installed = True  # don't retry every call


class _CompileClock:
    """Accumulates jax compile/trace/lower seconds while the scope is open."""

    def __init__(self) -> None:
        self.total = 0.0
        self._acc = [0.0]

    def __enter__(self) -> "_CompileClock":
        _install_listener()
        with _compile_lock:
            _compile_accs.append(self._acc)
        return self

    def __exit__(self, *exc) -> bool:
        with _compile_lock:
            _compile_accs.remove(self._acc)
        self.total = self._acc[0]
        return False


# public name: the serving layer (repro/serve) opens the same clock around
# each session's execution so per-query compile seconds keep the one-shot
# facade's accounting discipline
CompileClock = _CompileClock


def serve(db: Database, config=None, **kwargs) -> "object":
    """Open a long-lived query-serving facade over ``db``: a
    :class:`repro.serve.JoinServer` with a compile-once plan cache
    (isomorphic queries share engines), cross-query persistent tier-2
    tables (snapshot save/load survives the process), and bounded
    concurrent streaming sessions.  ``config`` is a
    :class:`repro.configs.paper_clftj.JoinEngineConfig`; remaining keyword
    arguments are forwarded to :class:`~repro.serve.JoinServer`."""
    from ..serve import JoinServer  # lazy: serve imports this module

    return JoinServer(db, config=config, **kwargs)


def plan_query(q: CQ, db: Optional[Database] = None,
               max_adhesion: int = 2,
               ) -> Tuple[TreeDecomposition, Tuple[str, ...]]:
    stats = db.stats() if db is not None else None
    return choose_plan(q, stats, max_adhesion=max_adhesion)


def _plan(q: CQ, db: Database, td, order):
    if td is None or order is None:
        td_, order_ = plan_query(q, db)
        td = td if td is not None else td_
        order = order if order is not None else order_
    return td, tuple(order)


def count(q: CQ, db: Database, algorithm: str = "clftj",
          backend: str = "jax",
          td: Optional[TreeDecomposition] = None,
          order: Optional[Sequence[str]] = None,
          policy: Optional[CachePolicy] = None,
          capacity: int = 1 << 16,
          dedup: bool = True, impl: str = "bsearch",
          cache: Optional[CacheConfig] = None,
          expand_kernel: str = "auto") -> Result:
    """Count ``q`` over ``db``.  ``cache`` configures the tier-2 cache of the
    JAX engine (policy / associativity / slots / dynamic budget); for the
    ``ref`` backend it is mapped onto the paper's :class:`CachePolicy`
    unless an explicit ``policy`` is given.  ``expand_kernel`` selects the
    EXPAND kernel path of the JAX engines (``"auto"`` dispatches per
    platform/spec through ``kernels/registry.py``; the chosen path lands
    in ``Result.expand_paths``)."""
    t0 = time.perf_counter()
    counters = Counters()
    td, order = _plan(q, db, td, order)
    t1 = time.perf_counter()
    with _CompileClock() as cc:
        if algorithm == "clftj":
            if backend == "jax":
                eng = JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                                        dedup=dedup, impl=impl, cache=cache,
                                        expand_kernel=expand_kernel)
                c = eng.count()
                counters_out = dict(eng.stats)
            else:
                if policy is None and cache is not None:
                    policy = CachePolicy.from_cache_config(cache)
                c = CLFTJ(q, td, order, db, policy, counters).count()
                counters_out = counters.snapshot()
        elif algorithm == "lftj":
            if backend == "jax":
                eng = JaxTrieJoin(q, order, db, capacity=capacity,
                                  impl=impl, expand_kernel=expand_kernel)
                c = eng.count()
                counters_out = {f"expand_calls_{k}": v for k, v in
                                eng.expand_call_counts().items()}
            else:
                c = LFTJ(q, order, db, counters).count()
                counters_out = counters.snapshot()
        elif algorithm == "ytd":
            c = YTD(q, td, db, counters).count()
            counters_out = counters.snapshot()
        else:
            raise ValueError(algorithm)
    t2 = time.perf_counter()
    return Result(count=c, tuples=None, algorithm=algorithm, backend=backend,
                  order=order, td=td, counters=counters_out,
                  wall_s=t2 - t0, plan_s=t1 - t0, compile_s=cc.total,
                  exec_s=max(0.0, (t2 - t1) - cc.total))


def evaluate(q: CQ, db: Database, algorithm: str = "clftj",
             backend: str = "ref",
             td: Optional[TreeDecomposition] = None,
             order: Optional[Sequence[str]] = None,
             policy: Optional[CachePolicy] = None,
             capacity: int = 1 << 16, impl: str = "bsearch",
             dedup: bool = True,
             cache: Optional[CacheConfig] = None,
             expand_kernel: str = "auto") -> Result:
    """Materialize ``q``'s full result.  ``backend="jax"`` runs the
    schedule executor in evaluation mode (tier-1 representatives replayed
    as row blocks); tuples are identical to the host oracle's.  With
    ``cache=CacheConfig(cache_payloads=True)`` tier 2 serves evaluation
    too — recurring subjoins splice their cached factorized blocks
    instead of re-expanding (``Result.tier2_replay_hits``)."""
    t0 = time.perf_counter()
    counters = Counters()
    td, order = _plan(q, db, td, order)
    t1 = time.perf_counter()
    counters_out: Dict[str, int] = {}
    with _CompileClock() as cc:
        if algorithm == "clftj":
            if backend == "jax":
                eng = JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                                        dedup=dedup, impl=impl, cache=cache,
                                        expand_kernel=expand_kernel)
                blocks = list(eng.evaluate())
                rows = (np.concatenate(blocks, axis=0) if blocks
                        else np.zeros((0, len(order)), np.int32))
                counters_out = dict(eng.stats)
            else:
                rows = np.asarray(
                    list(CLFTJ(q, td, order, db, policy, counters)
                         .evaluate()),
                    dtype=np.int64).reshape(-1, len(order))
                counters_out = counters.snapshot()
        elif algorithm == "lftj":
            if backend == "jax":
                from .frontier import jax_lftj_evaluate
                rows = jax_lftj_evaluate(q, order, db, capacity=capacity,
                                         impl=impl,
                                         expand_kernel=expand_kernel)
            else:
                rows = np.asarray(
                    list(LFTJ(q, order, db, counters).evaluate()),
                    dtype=np.int64).reshape(-1, len(order))
                counters_out = counters.snapshot()
        elif algorithm == "ytd":
            ytd_rows = YTD(q, td, db, counters).evaluate()
            rows = np.asarray(ytd_rows, dtype=np.int64).reshape(
                -1, len(q.variables))
            counters_out = counters.snapshot()
        else:
            raise ValueError(algorithm)
    t2 = time.perf_counter()
    return Result(count=rows.shape[0], tuples=rows, algorithm=algorithm,
                  backend=backend, order=order, td=td,
                  counters=counters_out,
                  wall_s=t2 - t0, plan_s=t1 - t0, compile_s=cc.total,
                  exec_s=max(0.0, (t2 - t1) - cc.total))


@dataclass
class ResultStream:
    """The streaming-evaluation surface (DESIGN.md §2.8): iterate to
    receive (k, n) int32 result morsels in arrival order; once exhausted,
    ``result`` holds the :class:`Result` with the exact one-shot count
    and counters and ``tuples=None`` — the rows were already streamed.
    Timing caveat: the stream is consumer-driven, so ``exec_s``/
    ``wall_s`` span the whole drain *including time the consumer spends
    between morsels* — comparable to one-shot numbers only when the
    consumer iterates promptly."""

    order: Tuple[str, ...]
    _gen: Iterator[np.ndarray] = field(repr=False)
    result: Optional[Result] = None

    def __iter__(self) -> Iterator[np.ndarray]:
        return self._gen


def evaluate_stream(q: CQ, db: Database, algorithm: str = "clftj",
                    backend: str = "jax",
                    td: Optional[TreeDecomposition] = None,
                    order: Optional[Sequence[str]] = None,
                    capacity: int = 1 << 16, impl: str = "bsearch",
                    dedup: bool = True,
                    cache: Optional[CacheConfig] = None,
                    expand_kernel: str = "auto",
                    emit_in_flight: int = 8) -> ResultStream:
    """Evaluate ``q`` as a *stream*: returns a :class:`ResultStream` whose
    iterator yields materialized (k, n) int32 morsels in arrival order —
    each block's device→host copy issued asynchronously as the executor
    produces it, at most ``emit_in_flight`` copies in flight — instead of
    buffering the whole result.  Only the JAX backend streams (the host
    reference engines have no device→host copy to overlap)."""
    if backend != "jax" or algorithm not in ("clftj", "lftj"):
        raise ValueError(
            f"evaluate_stream supports the JAX clftj/lftj engines only, "
            f"got algorithm={algorithm!r} backend={backend!r}")
    t0 = time.perf_counter()
    td_, order_ = _plan(q, db, td, order)
    t1 = time.perf_counter()
    stream = ResultStream(order=order_, _gen=iter(()))

    def _gen() -> Iterator[np.ndarray]:
        n_rows = 0
        with _CompileClock() as cc:
            if algorithm == "clftj":
                eng = JaxCachedTrieJoin(q, td_, order_, db,
                                        capacity=capacity, dedup=dedup,
                                        impl=impl, cache=cache,
                                        expand_kernel=expand_kernel,
                                        emit_in_flight=emit_in_flight)
            else:
                eng = JaxTrieJoin(q, order_, db, capacity=capacity,
                                  impl=impl, expand_kernel=expand_kernel,
                                  emit_in_flight=emit_in_flight)
            for block in eng.evaluate_stream():
                n_rows += block.shape[0]
                yield block
            counters_out = dict(getattr(eng, "stats", {}) or {})
        t2 = time.perf_counter()
        stream.result = Result(
            count=n_rows, tuples=None, algorithm=algorithm, backend=backend,
            order=order_, td=td_, counters=counters_out,
            wall_s=t2 - t0, plan_s=t1 - t0, compile_s=cc.total,
            exec_s=max(0.0, (t2 - t1) - cc.total))

    stream._gen = _gen()
    return stream

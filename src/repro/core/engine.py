"""Public join-engine API: plan + execute CLFTJ/LFTJ/YTD on any backend.

    from repro.core import engine
    res = engine.count(q, db)                     # plans a TD, runs JAX CLFTJ
    res = engine.count(q, db, algorithm="lftj")   # vanilla trie join
    res = engine.count(q, db, backend="ref")      # paper-faithful host engines
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cache import CacheConfig
from .cq import CQ
from .clftj_ref import CLFTJ, CachePolicy
from .cached_frontier import JaxCachedTrieJoin
from .db import Counters, Database
from .decompose import choose_plan
from .frontier import JaxTrieJoin
from .lftj_ref import LFTJ
from .td import TreeDecomposition
from .yannakakis import YTD


@dataclass
class Result:
    count: Optional[int]
    tuples: Optional[np.ndarray]
    algorithm: str
    backend: str
    order: Tuple[str, ...]
    td: Optional[TreeDecomposition]
    counters: Dict[str, int] = field(default_factory=dict)
    wall_s: float = 0.0


def plan_query(q: CQ, db: Optional[Database] = None,
               max_adhesion: int = 2,
               ) -> Tuple[TreeDecomposition, Tuple[str, ...]]:
    stats = db.stats() if db is not None else None
    return choose_plan(q, stats, max_adhesion=max_adhesion)


def count(q: CQ, db: Database, algorithm: str = "clftj",
          backend: str = "jax",
          td: Optional[TreeDecomposition] = None,
          order: Optional[Sequence[str]] = None,
          policy: Optional[CachePolicy] = None,
          capacity: int = 1 << 16, cache_slots: int = 1 << 16,
          dedup: bool = True, impl: str = "bsearch",
          cache: Optional[CacheConfig] = None) -> Result:
    """Count ``q`` over ``db``.  ``cache`` configures the tier-2 cache of the
    JAX engine (policy / associativity / slots / dynamic budget); for the
    ``ref`` backend it is mapped onto the paper's :class:`CachePolicy`
    unless an explicit ``policy`` is given."""
    import time
    t0 = time.perf_counter()
    counters = Counters()
    if td is None or order is None:
        td_, order_ = plan_query(q, db)
        td = td if td is not None else td_
        order = order if order is not None else order_
    order = tuple(order)
    if algorithm == "clftj":
        if backend == "jax":
            eng = JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                                    cache_slots=cache_slots, dedup=dedup,
                                    impl=impl, cache=cache)
            c = eng.count()
            counters_out = dict(eng.stats)
        else:
            if policy is None and cache is not None:
                policy = CachePolicy.from_cache_config(cache)
            c = CLFTJ(q, td, order, db, policy, counters).count()
            counters_out = counters.snapshot()
    elif algorithm == "lftj":
        if backend == "jax":
            c = JaxTrieJoin(q, order, db, capacity=capacity,
                            impl=impl).count()
            counters_out = {}
        else:
            c = LFTJ(q, order, db, counters).count()
            counters_out = counters.snapshot()
    elif algorithm == "ytd":
        c = YTD(q, td, db, counters).count()
        counters_out = counters.snapshot()
    else:
        raise ValueError(algorithm)
    return Result(count=c, tuples=None, algorithm=algorithm, backend=backend,
                  order=order, td=td, counters=counters_out,
                  wall_s=time.perf_counter() - t0)


def evaluate(q: CQ, db: Database, algorithm: str = "clftj",
             backend: str = "ref",
             td: Optional[TreeDecomposition] = None,
             order: Optional[Sequence[str]] = None,
             policy: Optional[CachePolicy] = None,
             capacity: int = 1 << 16, impl: str = "bsearch") -> Result:
    import time
    t0 = time.perf_counter()
    counters = Counters()
    if td is None or order is None:
        td_, order_ = plan_query(q, db)
        td = td if td is not None else td_
        order = order if order is not None else order_
    order = tuple(order)
    if algorithm == "clftj":
        rows = np.asarray(
            list(CLFTJ(q, td, order, db, policy, counters).evaluate()),
            dtype=np.int64).reshape(-1, len(order))
    elif algorithm == "lftj":
        if backend == "jax":
            from .frontier import jax_lftj_evaluate
            rows = jax_lftj_evaluate(q, order, db, capacity=capacity,
                                     impl=impl)
        else:
            rows = np.asarray(list(LFTJ(q, order, db, counters).evaluate()),
                              dtype=np.int64).reshape(-1, len(order))
    elif algorithm == "ytd":
        ytd_rows = YTD(q, td, db, counters).evaluate()
        rows = np.asarray(ytd_rows, dtype=np.int64).reshape(-1, len(q.variables))
    else:
        raise ValueError(algorithm)
    return Result(count=rows.shape[0], tuples=rows, algorithm=algorithm,
                  backend=backend, order=order, td=td,
                  counters=counters.snapshot(),
                  wall_s=time.perf_counter() - t0)

"""Ordered tree decompositions (paper §2.3).

A TD of a full CQ q is ⟨t, χ⟩ with (1) every subgoal's vars inside some bag,
(2) for every variable the bags containing it induce a connected subtree.
An *ordered* TD roots and orders t; adhesion(v) = χ(v) ∩ χ(parent(v)).
owner(x) = the preorder-minimal bag containing x.  A TD is *strongly
compatible* with an ordering ⟨x1..xn⟩ iff owner(x_i) ≺pre owner(x_j) ⇒ i < j.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .cq import CQ


@dataclass
class TreeDecomposition:
    """Rooted, ordered tree decomposition.

    ``parent[v]`` is -1 for the root; ``children[v]`` is ordered (tree order).
    ``bags[v]`` is the bag χ(v).
    """

    bags: List[FrozenSet[str]]
    parent: List[int]
    children: List[List[int]] = field(default_factory=list)

    def __post_init__(self):
        n = len(self.bags)
        if len(self.parent) != n:
            raise ValueError("parent/bags length mismatch")
        if not self.children:
            self.children = [[] for _ in range(n)]
            for v in range(n):
                if self.parent[v] >= 0:
                    self.children[self.parent[v]].append(v)
        roots = [v for v in range(n) if self.parent[v] < 0]
        if len(roots) != 1:
            raise ValueError(f"expected exactly one root, got {roots}")
        self._root = roots[0]

    # -- basic structure ----------------------------------------------------
    @property
    def root(self) -> int:
        return self._root

    @property
    def num_nodes(self) -> int:
        return len(self.bags)

    def preorder(self) -> List[int]:
        """Nodes in preorder (≺pre of the paper), respecting child order."""
        out: List[int] = []
        stack = [self.root]
        while stack:
            v = stack.pop()
            out.append(v)
            stack.extend(reversed(self.children[v]))
        return out

    def adhesion(self, v: int) -> FrozenSet[str]:
        """χ(v) ∩ χ(parent(v)); empty for the root."""
        p = self.parent[v]
        if p < 0:
            return frozenset()
        return self.bags[v] & self.bags[p]

    def adhesions(self) -> List[FrozenSet[str]]:
        return [self.adhesion(v) for v in range(self.num_nodes)]

    def max_adhesion_size(self) -> int:
        return max((len(self.adhesion(v)) for v in range(self.num_nodes)
                    if self.parent[v] >= 0), default=0)

    def width(self) -> int:
        """Treewidth-style width: max bag size - 1."""
        return max(len(b) for b in self.bags) - 1

    def depth(self) -> int:
        d = {self.root: 0}
        for v in self.preorder()[1:]:
            d[v] = d[self.parent[v]] + 1
        return max(d.values())

    def subtree_nodes(self, v: int) -> List[int]:
        out = []
        stack = [v]
        while stack:
            u = stack.pop()
            out.append(u)
            stack.extend(reversed(self.children[u]))
        return out

    # -- owners & orderings ---------------------------------------------------
    def owners(self) -> Dict[str, int]:
        """owner(x) = preorder-minimal bag containing x."""
        owner: Dict[str, int] = {}
        for v in self.preorder():
            for x in self.bags[v]:
                owner.setdefault(x, v)
        return owner

    def owned_vars(self) -> Dict[int, List[str]]:
        """Variables owned per node, each list sorted for determinism."""
        owner = self.owners()
        out: Dict[int, List[str]] = {v: [] for v in range(self.num_nodes)}
        for x, v in owner.items():
            out[v].append(x)
        for v in out:
            out[v].sort()
        return out

    def strongly_compatible_order(
            self, within_bag: Optional[Dict[int, Sequence[str]]] = None,
    ) -> Tuple[str, ...]:
        """Emit a variable ordering the TD is strongly compatible with.

        Walk the preorder; at each node emit its owned variables.  Any
        within-bag order is legal (owners are all equal); callers may pass one
        (e.g. from a cost model), else sorted order is used.
        """
        owned = self.owned_vars()
        order: List[str] = []
        for v in self.preorder():
            vs = list(within_bag[v]) if within_bag and v in within_bag else owned[v]
            if sorted(vs) != sorted(owned[v]):
                raise ValueError(f"within_bag[{v}] must permute owned vars")
            order.extend(vs)
        return tuple(order)

    def is_compatible(self, order: Sequence[str]) -> bool:
        """Joglekar-et-al compatibility: owner parent-of owner ⇒ earlier."""
        pos = {x: i for i, x in enumerate(order)}
        owner = self.owners()
        for xi in order:
            for xj in order:
                oi, oj = owner[xi], owner[xj]
                if self.parent[oj] == oi and pos[xi] >= pos[xj] and oi != oj:
                    return False
        return True

    def is_strongly_compatible(self, order: Sequence[str]) -> bool:
        """owner(x_i) ≺pre owner(x_j) ⇒ i < j (paper §2.3)."""
        pos = {x: i for i, x in enumerate(order)}
        pre_rank = {v: r for r, v in enumerate(self.preorder())}
        owner = self.owners()
        for xi in order:
            for xj in order:
                if pre_rank[owner[xi]] < pre_rank[owner[xj]] and pos[xi] >= pos[xj]:
                    return False
        return True

    # -- validity -------------------------------------------------------------
    def validate(self, q: CQ) -> None:
        """Raise if not a valid TD of q (both paper conditions)."""
        allvars = set(q.variables)
        bagvars = set().union(*self.bags) if self.bags else set()
        if bagvars != allvars:
            raise ValueError(f"bag vars {bagvars} != query vars {allvars}")
        for atom in q.atoms:
            if not any(set(atom.vars) <= b for b in self.bags):
                raise ValueError(f"no bag covers atom {atom}")
        # connectedness: for each var, bags containing it form a subtree.
        for x in allvars:
            holders = [v for v in range(self.num_nodes) if x in self.bags[v]]
            hs = set(holders)
            # the subtree condition holds iff all holders minus the
            # preorder-minimal one have their parent's path reaching another
            # holder through holders only; equivalently: each holder except
            # the shallowest has a parent in the holder set once we take the
            # holder closest to the root as the subtree root.
            pre_rank = {v: r for r, v in enumerate(self.preorder())}
            top = min(holders, key=lambda v: pre_rank[v])
            for v in holders:
                if v == top:
                    continue
                if self.parent[v] not in hs:
                    raise ValueError(
                        f"variable {x}: bags {holders} not connected (node {v})")

    # -- cleanup ----------------------------------------------------------------
    def eliminate_redundant_bags(self) -> "TreeDecomposition":
        """Remove bags contained in an adjacent bag (paper §4.1 remark).

        Children of a removed bag re-attach to the surviving neighbour.
        Applied to fixpoint.
        """
        bags = [set(b) for b in self.bags]
        parent = list(self.parent)
        children = [list(c) for c in self.children]
        alive = [True] * len(bags)

        changed = True
        while changed:
            changed = False
            for v in range(len(bags)):
                if not alive[v]:
                    continue
                p = parent[v]
                # child contained in parent -> merge child into parent
                if p >= 0 and alive[p] and bags[v] <= bags[p]:
                    children[p].remove(v)
                    for c in children[v]:
                        parent[c] = p
                        children[p].append(c)
                    children[v] = []
                    alive[v] = False
                    changed = True
                    continue
                # parent contained in (only) child -> merge parent into child
                if p >= 0 and alive[p] and bags[p] <= bags[v] and \
                        len(children[p]) == 1 and parent[p] >= 0:
                    gp = parent[p]
                    children[gp][children[gp].index(p)] = v
                    parent[v] = gp
                    alive[p] = False
                    changed = True

        # root containment: if root's bag ⊆ its single child, drop the root
        # (handled by re-rooting).
        idx = {v: i for i, v in enumerate([v for v in range(len(bags)) if alive[v]])}
        new_bags = [frozenset(bags[v]) for v in range(len(bags)) if alive[v]]
        new_parent = [idx[parent[v]] if parent[v] >= 0 else -1
                      for v in range(len(bags)) if alive[v]]
        return TreeDecomposition(new_bags, new_parent)


def singleton_td(variables: Sequence[str]) -> TreeDecomposition:
    """The trivial one-bag decomposition (paper Fig 4, line 3)."""
    return TreeDecomposition([frozenset(variables)], [-1])

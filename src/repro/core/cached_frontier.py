"""Vectorized CLFTJ in JAX — adhesion-keyed memoization for the frontier join.

TPU-native realization of the paper's Figure 2 (see DESIGN.md §2):

* **Tier 1 — intra-chunk dedup.**  On entering TD node ``c`` the frontier rows
  sharing an adhesion key μ|α are collapsed to unique representatives; the
  subtree is expanded once per distinct key and the resulting per-rep counts
  are scattered back as factor multipliers.  This is the paper's reuse
  executed as sort/segment data-parallel work, with zero persistent memory.

* **Tier 2 — persistent bounded cache.**  A pluggable device table per TD
  node (``core/cache.py``) — the paper's *dynamic cache size* knob (Fig 10)
  plus its admission/eviction flexibility (§3.4): direct-mapped,
  set-associative-LRU, or cost-aware, with an optional sizing controller
  that grows/shrinks tables between subtree launches under a slot budget.
  Caching is optional so correctness is unaffected.  Per the paper's own
  implementation, only adhesions of dimension <= 2 are cached (the packed
  int64 key limit).

Both tiers preserve LFTJ's guarantees: they only ever *skip recomputation of
subtrees whose count is already known*, exactly like the paper's cache[α, μ|α].

Control flow lives in ``core/schedule.py`` (DESIGN.md §2.5): the TD + order
are lowered once into a linear op schedule and this class only supplies the
data plane — the :class:`~.schedule.ScheduleExecutor` interprets the ops,
with both memoization tiers as executor capabilities.  ``evaluate()`` runs
the same schedule in materialization mode: tier-1 representatives are
replayed as row blocks through ``orig`` (the paper §3.4's factorized
intermediates), so the JAX engine now answers full-evaluation workloads —
and with ``CacheConfig(cache_payloads=True)`` tier 2 serves evaluation as
well, replaying cached factorized row blocks from the per-node slab arena
on every recurring adhesion key (DESIGN.md §2.6).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp
from jax.experimental import enable_x64

from .cache import CacheConfig, CacheManager
from .cq import CQ
from .clftj_ref import Plan
from .db import Database
from .frontier import Frontier, JaxTrieJoin, MAX_KEY_BITS
from .schedule import ScheduleExecutor, lower
from .td import TreeDecomposition

__all__ = ["JaxCachedTrieJoin", "jax_clftj_count", "jax_clftj_evaluate",
           "MAX_KEY_BITS"]

def _resolve_cache_config(cache: Optional[CacheConfig],
                          cached_nodes: Optional[frozenset],
                          default_slots: int) -> CacheConfig:
    """Default the tier-2 config and merge an explicit node filter.  (The
    legacy ``cache_slots`` int and its one-release DeprecationWarning
    shim were removed after the promised window — pass
    ``cache=CacheConfig(...)``.)"""
    if cache is None:
        cache = CacheConfig(policy="direct", slots=default_slots,
                            enabled_nodes=cached_nodes)
    elif cached_nodes is not None and cache.enabled_nodes is None:
        from dataclasses import replace as _replace
        cache = _replace(cache, enabled_nodes=cached_nodes)
    return cache


class JaxCachedTrieJoin(JaxTrieJoin):
    """CLFTJ over the frontier engine.

    Tier 2 is configured by ``cache`` (a :class:`CacheConfig`;
    ``slots=0`` disables tier 2).  ``dedup=False`` disables tier 1 (then
    it degenerates to vanilla LFTJ with per-subtree counting).
    ``expand_kernel`` selects the EXPAND kernel path
    (``"auto"|"pallas"|"xla"`` — kernels/registry.py)."""

    def __init__(self, q: CQ, td: TreeDecomposition, order: Sequence[str],
                 db: Database, capacity: int = 1 << 17, dedup: bool = True,
                 impl: str = "bsearch",
                 cached_nodes: Optional[frozenset] = None,
                 cache: Optional[CacheConfig] = None,
                 expand_kernel: str = "auto", emit_in_flight: int = 8):
        super().__init__(q, order, db, capacity=capacity, impl=impl,
                         expand_kernel=expand_kernel,
                         emit_in_flight=emit_in_flight)
        self.plan = Plan.build(td, order)
        self.td = td
        cache = _resolve_cache_config(cache, cached_nodes,
                                      default_slots=1 << 16)
        self.dedup = dedup
        maxval = max((int(r.max()) if r.size else 0) for r in self.atom_rows)
        # keys that don't pack into int64 fields would alias distinct
        # adhesion assignments — both tiers must stay off (tier-1 dedup on
        # corrupted keys could merge rows that are not duplicates)
        self._keys_packable = maxval < (1 << MAX_KEY_BITS)
        self.cache_config = cache
        self.cache = CacheManager(cache)
        self.cache.expected_tables = sum(
            1 for v in range(td.num_nodes)
            if td.parent[v] >= 0 and self._node_cacheable(v))
        # the tentpole: TD + order lowered ONCE into the shared op schedule
        self.schedule = lower(self.n, plan=self.plan,
                              cacheable=self._node_cacheable,
                              dedup=self.dedup)
        self.stats = {"tier1_rows_collapsed": 0, "tier2_hits": 0,
                      "tier2_misses": 0, "tier2_probes": 0,
                      "tier2_inserts": 0, "tier2_evictions": 0,
                      "tier2_resizes": 0, "tier2_slots": 0,
                      "tier2_replay_hits": 0, "tier2_payload_flushes": 0,
                      "tier2_payload_skips": 0, "tier2_payload_throttled": 0,
                      "tier2_slab_rows": 0, "subtree_launches": 0,
                      "expand_calls_pallas": 0, "expand_calls_xla": 0}

    # -----------------------------------------------------------------
    def _node_cacheable(self, v: int) -> bool:
        """Can node v's adhesion be keyed at all (tier 1 *or* tier 2)?
        Independent of the slot count: ``slots=0`` disables only
        tier 2, never tier-1 dedup."""
        if not self._keys_packable:
            return False
        en = self.cache_config.enabled_nodes
        if en is not None and v not in en:
            return False
        return len(self.plan.adhesion_idx[v]) <= 2

    def _finalize(self, ex: ScheduleExecutor) -> None:
        agg = self.cache.stats()
        self.stats["tier2_hits"] = agg["hits"]
        self.stats["tier2_misses"] = agg["misses"]
        self.stats["tier2_probes"] = agg["probes"]
        self.stats["tier2_inserts"] = agg["inserts"]
        self.stats["tier2_evictions"] = agg["evictions"]
        self.stats["tier2_resizes"] = agg["resizes"]
        self.stats["tier2_slots"] = agg["slots"]
        self.stats["tier2_replay_hits"] = agg.get("payload_hits", 0)
        self.stats["tier2_payload_flushes"] = agg.get("payload_flushes", 0)
        self.stats["tier2_payload_skips"] = agg.get("payload_skips", 0)
        self.stats["tier2_payload_throttled"] = agg.get(
            "payload_throttled", 0)
        self.stats["tier2_slab_rows"] = agg.get("slab_rows", 0)
        self.stats["tier1_rows_collapsed"] += ex.t1_rows_collapsed()
        self.stats["subtree_launches"] += ex.subtree_launches
        for path, runs in ex.expand_path_runs.items():
            self.stats[f"expand_calls_{path}"] = (
                self.stats.get(f"expand_calls_{path}", 0) + runs)

    # -----------------------------------------------------------------
    def count(self) -> int:
        with enable_x64():
            ex = ScheduleExecutor(self, mode="count")
            self.last_executor = ex  # op_runs / sync diagnostics
            total = ex.count()
            self._finalize(ex)
            return total

    def evaluate(self) -> Iterator[np.ndarray]:
        """Yields (k, n) int32 blocks of result assignments (order cols).

        Materialization mode of the same schedule: tier-1 representatives
        are replayed back through ``orig`` at every FOLD.  With
        ``cache=CacheConfig(cache_payloads=True)`` tier 2 participates
        too: recurring adhesion keys replay their cached factorized row
        blocks instead of re-expanding the bag (paper §3.4's evaluation
        discussion; ``stats["tier2_replay_hits"]`` counts the parent rows
        whose bag was served by splice — each such hit expands to its
        block's ``pay_len`` result rows).
        Count-only tables cannot replay tuples and are bypassed
        (optionality — the cache is never required for correctness)."""
        with enable_x64():
            ex = ScheduleExecutor(self, mode="evaluate")
            self.last_executor = ex
            yield from ex.evaluate()
            self._finalize(ex)

    def evaluate_stream(self) -> Iterator[np.ndarray]:
        """Streaming evaluation (DESIGN.md §2.8): identical blocks, in the
        same order, as :meth:`evaluate`, but each block's device→host copy
        is issued asynchronously as it is produced — bounded by
        ``emit_in_flight`` — so copies overlap the next morsel's EXPAND
        instead of draining at pass end.  All tier-2 behavior (payload
        probe/splice/store) is unchanged: streaming only moves the output
        data plane."""
        with enable_x64():
            ex = ScheduleExecutor(self, mode="evaluate")
            self.last_executor = ex
            try:
                yield from ex.evaluate_stream()
            finally:
                # a stream abandoned early (break / close) must still
                # fold whatever the executor did complete into stats —
                # stale previous-pass counters would read as current
                self._finalize(ex)


def jax_clftj_count(q: CQ, td: TreeDecomposition, order: Sequence[str],
                    db: Database, capacity: int = 1 << 17,
                    dedup: bool = True, impl: str = "bsearch",
                    cache: Optional[CacheConfig] = None,
                    expand_kernel: str = "auto") -> int:
    return JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                             dedup=dedup, impl=impl, cache=cache,
                             expand_kernel=expand_kernel).count()


def jax_clftj_evaluate(q: CQ, td: TreeDecomposition, order: Sequence[str],
                       db: Database, capacity: int = 1 << 17,
                       dedup: bool = True, impl: str = "bsearch",
                       cache: Optional[CacheConfig] = None,
                       expand_kernel: str = "auto") -> np.ndarray:
    """Materialize the full result as an (N, n) int32 array over ``order``
    columns — the JAX CLFTJ analogue of :func:`~.clftj_ref.clftj_evaluate`."""
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                            dedup=dedup, impl=impl, cache=cache,
                            expand_kernel=expand_kernel)
    blocks = list(eng.evaluate())
    if not blocks:
        return np.zeros((0, len(eng.order)), np.int32)
    return np.concatenate(blocks, axis=0)

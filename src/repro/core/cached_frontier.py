"""Vectorized CLFTJ in JAX — adhesion-keyed memoization for the frontier join.

TPU-native realization of the paper's Figure 2 (see DESIGN.md §2):

* **Tier 1 — intra-chunk dedup.**  On entering TD node ``c`` the frontier rows
  sharing an adhesion key μ|α are collapsed to unique representatives; the
  subtree is expanded once per distinct key and the resulting per-rep counts
  are scattered back as factor multipliers.  This is the paper's reuse
  executed as sort/segment data-parallel work, with zero persistent memory.

* **Tier 2 — persistent bounded cache.**  A pluggable device table per TD
  node (``core/cache.py``) — the paper's *dynamic cache size* knob (Fig 10)
  plus its admission/eviction flexibility (§3.4): direct-mapped,
  set-associative-LRU, or cost-aware, with an optional sizing controller
  that grows/shrinks tables between subtree launches under a slot budget.
  Caching is optional so correctness is unaffected.  Per the paper's own
  implementation, only adhesions of dimension <= 2 are cached (the packed
  int64 key limit).

Both tiers preserve LFTJ's guarantees: they only ever *skip recomputation of
subtrees whose count is already known*, exactly like the paper's cache[α, μ|α].
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from .cache import CacheConfig, CacheManager
from .cq import CQ
from .clftj_ref import Plan
from .db import Database
from .frontier import Frontier, JaxTrieJoin, MAX_KEY_BITS
from .td import TreeDecomposition


def _pack_keys(assign: jnp.ndarray, idx: Tuple[int, ...],
               node: int) -> jnp.ndarray:
    """Pack <=2 adhesion columns + node id into one int64 key."""
    key = jnp.full((assign.shape[0],), np.int64(node))
    for i in idx:
        key = (key << MAX_KEY_BITS) | assign[:, i].astype(jnp.int64)
    return key


@jax.jit
def _dedup(keys: jnp.ndarray, active: jnp.ndarray):
    """Unique active keys: returns (is_rep_sorted→orig layout helpers).

    Returns (first_idx, rep_of_row, n_reps):
      * ``first_idx[r]``   — row index of representative r (garbage for r >=
        n_reps),
      * ``rep_of_row[i]``  — representative id of row i (garbage if inactive),
      * ``n_reps``         — number of distinct active keys.
    """
    C = keys.shape[0]
    big = jnp.int64(2 ** 62)
    k = jnp.where(active, keys, big)  # inactive rows sort to the back
    order = jnp.argsort(k, stable=True)
    ks = k[order]
    isfirst = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    isfirst = isfirst & (ks != big)
    rep_sorted = jnp.cumsum(isfirst.astype(jnp.int32)) - 1
    n_reps = jnp.sum(isfirst.astype(jnp.int32))
    rep_of_row = jnp.zeros((C,), jnp.int32).at[order].set(rep_sorted)
    # first occurrence row index per rep (scatter-max; -1 writes are no-ops)
    first_idx = jnp.zeros((C,), jnp.int32).at[
        jnp.clip(rep_sorted, 0, C - 1)].max(
        jnp.where(isfirst, order, -1).astype(jnp.int32))
    return first_idx, rep_of_row, n_reps


@jax.jit
def _make_rep_frontier(F: Frontier, first_idx: jnp.ndarray,
                       n_reps: jnp.ndarray) -> Frontier:
    C = F.assign.shape[0]
    rep_valid = jnp.arange(C, dtype=jnp.int32) < n_reps
    src = jnp.clip(first_idx, 0, C - 1)
    return Frontier(assign=F.assign[src],
                    factor=jnp.where(rep_valid, 1, 0).astype(jnp.int64),
                    valid=rep_valid,
                    orig=jnp.arange(C, dtype=jnp.int32),
                    lo=F.lo[src], hi=F.hi[src])


@jax.jit
def _apply_counts(F: Frontier, hit, hvals, rep_of_row, cnt) -> Frontier:
    mult = jnp.where(hit, hvals, cnt[jnp.clip(rep_of_row, 0, cnt.shape[0] - 1)])
    factor = F.factor * mult
    return F._replace(factor=factor, valid=F.valid & (factor > 0))


@functools.partial(jax.jit, static_argnames=("n_slots",))
def _segment_counts(exit_F: Frontier, n_slots: int) -> jnp.ndarray:
    contrib = jnp.where(exit_F.valid, exit_F.factor, 0)
    return jnp.zeros((n_slots,), jnp.int64).at[
        jnp.clip(exit_F.orig, 0, n_slots - 1)].add(contrib)


class JaxCachedTrieJoin(JaxTrieJoin):
    """CLFTJ over the frontier engine.

    Tier 2 is configured by ``cache`` (a :class:`CacheConfig`); the legacy
    ``cache_slots`` int is still accepted and maps to a direct-mapped config
    (``cache_slots=0`` disables tier 2).  ``dedup=False`` disables tier 1
    (then it degenerates to vanilla LFTJ with per-subtree counting)."""

    def __init__(self, q: CQ, td: TreeDecomposition, order: Sequence[str],
                 db: Database, capacity: int = 1 << 17,
                 cache_slots: int = 1 << 16, dedup: bool = True,
                 impl: str = "bsearch",
                 cached_nodes: Optional[frozenset] = None,
                 cache: Optional[CacheConfig] = None):
        super().__init__(q, order, db, capacity=capacity, impl=impl)
        self.plan = Plan.build(td, order)
        self.td = td
        if cache is None:
            cache = CacheConfig(policy="direct", slots=int(cache_slots),
                                enabled_nodes=cached_nodes)
        elif cached_nodes is not None and cache.enabled_nodes is None:
            from dataclasses import replace as _replace
            cache = _replace(cache, enabled_nodes=cached_nodes)
        self.dedup = dedup
        maxval = max((int(r.max()) if r.size else 0) for r in self.atom_rows)
        # keys that don't pack into int64 fields would alias distinct
        # adhesion assignments — both tiers must stay off (tier-1 dedup on
        # corrupted keys could merge rows that are not duplicates)
        self._keys_packable = maxval < (1 << MAX_KEY_BITS)
        self.cache_config = cache
        self.cache = CacheManager(cache)
        self.cache.expected_tables = sum(
            1 for v in range(td.num_nodes)
            if td.parent[v] >= 0 and self._node_cacheable(v))
        self.stats = {"tier1_rows_collapsed": 0, "tier2_hits": 0,
                      "tier2_misses": 0, "tier2_probes": 0,
                      "tier2_inserts": 0, "tier2_evictions": 0,
                      "tier2_resizes": 0, "tier2_slots": 0,
                      "subtree_launches": 0}

    @property
    def cache_slots(self) -> int:
        """Current total tier-2 slots (live tables, else the configured
        initial size) — kept as a property for legacy callers."""
        if self.cache.tables:
            return self.cache.total_slots()
        return self.cache_config.initial_slots()

    # -----------------------------------------------------------------
    def _node_cacheable(self, v: int) -> bool:
        """Can node v's adhesion be keyed at all (tier 1 *or* tier 2)?
        Independent of cache_slots: ``cache_slots=0`` disables only
        tier 2, never tier-1 dedup."""
        if not self._keys_packable:
            return False
        en = self.cache_config.enabled_nodes
        if en is not None and v not in en:
            return False
        return len(self.plan.adhesion_idx[v]) <= 2

    def _owned_depths(self, v: int) -> List[int]:
        if v not in self.plan.first_d:
            return []
        return list(range(self.plan.first_d[v], self.plan.last_d[v] + 1))

    def _finalize_stats(self) -> None:
        agg = self.cache.stats()
        self.stats["tier2_hits"] = agg["hits"]
        self.stats["tier2_misses"] = agg["misses"]
        self.stats["tier2_probes"] = agg["probes"]
        self.stats["tier2_inserts"] = agg["inserts"]
        self.stats["tier2_evictions"] = agg["evictions"]
        self.stats["tier2_resizes"] = agg["resizes"]
        self.stats["tier2_slots"] = agg["slots"]

    # -----------------------------------------------------------------
    def count(self) -> int:
        with enable_x64():
            total = 0
            for exitF in self._run_node(self.td.root,
                                        [self.initial_frontier()]):
                total += int(jnp.sum(jnp.where(exitF.valid, exitF.factor, 0)))
            self._finalize_stats()
            return total

    def _run_node(self, v: int, chunks: List[Frontier]) -> List[Frontier]:
        """Expand node v's own vars, then fold each child subtree into
        factors; returns chunks at depth subtree_last(v)+1."""
        for d in self._owned_depths(v):
            nxt: List[Frontier] = []
            for F in chunks:
                for piece in self.expand_chunks(F, d):
                    if bool(piece.valid.any()):
                        nxt.append(piece)
            chunks = nxt
        for c in self.td.children[v]:
            chunks = [self._enter_child(c, F) for F in chunks]
            chunks = [F for F in chunks if bool(F.valid.any())]
        return chunks

    def _enter_child(self, c: int, F: Frontier) -> Frontier:
        """Paper Fig 2 lines 6-12 & 20-22, vectorized over the chunk."""
        self.stats["subtree_launches"] += 1
        C = self.capacity
        adh = self.plan.adhesion_idx[c]
        cacheable = self._node_cacheable(c)
        use_t2 = cacheable and self.cache.enabled
        use_t1 = self.dedup and cacheable

        keys = _pack_keys(F.assign, adh, c) if cacheable else None
        if use_t2:
            hit, hvals = self.cache.get(c).probe(keys, F.valid)
        else:
            hit = jnp.zeros((C,), bool)
            hvals = jnp.zeros((C,), jnp.int64)

        active = F.valid & ~hit
        if use_t1:
            first_idx, rep_of_row, n_reps = _dedup(keys, active)
            self.stats["tier1_rows_collapsed"] += int(
                jnp.sum(active.astype(jnp.int32)) - n_reps)
            R = _make_rep_frontier(F, first_idx, n_reps)
        else:
            # identity "dedup": every active row is its own representative
            rep_of_row = jnp.arange(C, dtype=jnp.int32)
            R = F._replace(factor=jnp.where(active, 1, 0).astype(jnp.int64),
                           valid=active,
                           orig=jnp.arange(C, dtype=jnp.int32))

        cnt = jnp.zeros((C,), jnp.int64)
        if bool(R.valid.any()):
            for exitF in self._run_node(c, [R]):
                cnt = cnt + _segment_counts(exitF, C)

        if use_t2:
            rep_keys = keys[jnp.clip(first_idx, 0, C - 1)] if use_t1 else keys
            rep_active = (jnp.arange(C) < n_reps) if use_t1 else active
            self.cache.get(c).insert(rep_keys, cnt, rep_active)
            self.cache.maybe_resize(c)

        return _apply_counts(F, hit, hvals, rep_of_row, cnt)


def jax_clftj_count(q: CQ, td: TreeDecomposition, order: Sequence[str],
                    db: Database, capacity: int = 1 << 17,
                    cache_slots: int = 1 << 16, dedup: bool = True,
                    impl: str = "bsearch",
                    cache: Optional[CacheConfig] = None) -> int:
    return JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                             cache_slots=cache_slots, dedup=dedup,
                             impl=impl, cache=cache).count()

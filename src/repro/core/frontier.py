"""Vectorized (level-synchronous) trie join in JAX — the TPU-native LFTJ.

See DESIGN.md §2.  The depth-first RJoin recursion of the paper's Figure 1 is
re-derived as breadth-first *frontier expansion*: a frontier is a fixed
capacity matrix of partial assignments (+ per-atom trie ranges); expanding
variable ``x_d`` enumerates, for every row, the distinct candidate values of a
*guard* atom (via precomputed run-start arrays — the columnar trie) and
verifies membership in every other participating atom with batched bounded
binary search.  The expansion step itself is a kernel behind the dispatch
registry (``kernels/registry.py`` → fused Pallas or the XLA op chain in
``kernels/expand/``, per the ``expand_kernel`` knob; DESIGN.md §2.7).
The frontier after level d contains
exactly the depth-d partial assignments LFTJ would visit, so worst-case
optimality is inherited.  The static chunk capacity bounds *device* memory
per launch (each morsel is one fixed-shape chunk); the executor holds a
level's morsels on the host side of the schedule pass, so host/heap use
scales with the widest frontier level.  Evaluation mode either buffers
emitted ``(assign, valid)`` blocks until the pass completes
(``evaluate()``, one batched drain) or streams them
(``evaluate_stream()``: each block's device→host copy is issued
asynchronously as it is produced, bounded by ``emit_in_flight`` —
DESIGN.md §2.8).  A frontier row spliced
from the tier-2 payload slab (cached-subtree replay, DESIGN.md §2.6) is
indistinguishable downstream from one produced by expansion — the cache
only ever substitutes for recomputation.

Execution goes through the shared instruction schedule (DESIGN.md §2.5):
this class owns the *data plane* (tries, guard selection, the jitted
expansion step, morsel splitting); control flow — which op runs when, chunk
admission, count/evaluate emission — is ``core/schedule.py``'s
:class:`~.schedule.ScheduleExecutor` interpreting the lowered op list.

Counting uses 64-bit factors; engine entry points run under an
``enable_x64`` scope (the LM substrate stays 32-bit — the scope is local).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, NamedTuple, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..kernels import registry as kernels
from .cq import CQ
from .db import Database
from .schedule import MAX_KEY_BITS, ScheduleExecutor, lower

__all__ = ["MAX_KEY_BITS", "Frontier", "AtomLevel", "JaxTrieJoin",
           "jax_lftj_count", "jax_lftj_evaluate"]


class Frontier(NamedTuple):
    """One fixed-capacity chunk of partial assignments (a morsel)."""

    assign: jnp.ndarray   # (C, n) int32  — assignment columns (valid prefix)
    factor: jnp.ndarray   # (C,)  int64  — carried count factor (paper's f)
    valid: jnp.ndarray    # (C,)  bool
    orig: jnp.ndarray     # (C,)  int32  — origin row for segment aggregation
    lo: jnp.ndarray       # (C, m) int32 — per-atom trie range start
    hi: jnp.ndarray       # (C, m) int32 — per-atom trie range end


@dataclass(frozen=True)
class AtomLevel:
    """Columnar trie level: value column + run-start index (CSR)."""

    col: jnp.ndarray        # (N,) int32 — rows[:, level]
    runstarts: jnp.ndarray  # (R,) int32 — positions where rows[:, :level+1] changes
    col_np: np.ndarray
    runstarts_np: np.ndarray


def _build_levels(rows: np.ndarray) -> List[AtomLevel]:
    n, k = rows.shape
    levels = []
    for l in range(k):
        if n == 0:
            rs = np.zeros(0, dtype=np.int32)
        else:
            prefix = rows[:, :l + 1]
            change = np.ones(n, dtype=bool)
            change[1:] = (prefix[1:] != prefix[:-1]).any(axis=1)
            rs = np.flatnonzero(change).astype(np.int32)
        col = rows[:, l].astype(np.int32)
        levels.append(AtomLevel(jnp.asarray(col), jnp.asarray(rs), col, rs))
    return levels


class JaxTrieJoin:
    """Vectorized LFTJ: count / evaluate a full CQ over a fixed order."""

    def __init__(self, q: CQ, order: Sequence[str], db: Database,
                 capacity: int = 1 << 17, impl: str = "bsearch",
                 expand_kernel: str = "auto", emit_in_flight: int = 8):
        if expand_kernel not in kernels.EXPAND_MODES:
            raise ValueError(f"expand_kernel must be one of "
                             f"{kernels.EXPAND_MODES}, got {expand_kernel!r}")
        self.q = q
        self.order = tuple(order)
        self.n = len(self.order)
        self.db = db
        self.capacity = int(capacity)
        self.impl = impl
        self.expand_kernel = expand_kernel
        # streaming-emit bound: max in-flight device→host result-block
        # copies (DESIGN.md §2.8); consumed by ScheduleExecutor
        self.emit_in_flight = int(emit_in_flight)
        # depth -> impl the registry resolved for that EXPAND(d)
        self.expand_paths: Dict[int, str] = {}
        pos = {x: i for i, x in enumerate(self.order)}

        # per-atom tries, variables permuted into global order
        self.atom_rows: List[np.ndarray] = []
        self.atom_vars: List[Tuple[str, ...]] = []
        for a in q.atoms:
            uniq, first_col = [], {}
            for c, v in enumerate(a.vars):
                if v not in first_col:
                    first_col[v] = c
                    uniq.append(v)
            ordered = tuple(sorted(uniq, key=pos.get))
            rows = db.relations[a.relation]
            for c, v in enumerate(a.vars):
                if first_col[v] != c:
                    rows = rows[rows[:, c] == rows[:, first_col[v]]]
            rows = np.unique(rows[:, [first_col[v] for v in ordered]], axis=0)
            if rows.size and int(rows.max()) >= (1 << 31) - 1:
                raise ValueError("values must fit int32")
            self.atom_rows.append(rows.astype(np.int64))
            self.atom_vars.append(ordered)
        self.m = len(q.atoms)
        self.levels: List[List[AtomLevel]] = [
            _build_levels(r) for r in self.atom_rows]
        self.sizes = [r.shape[0] for r in self.atom_rows]

        # participants per depth; guard = the atom whose trie has the
        # DEEPEST bound prefix (most selective sibling list — LFTJ's seek
        # discipline), tie-broken by smaller relation.  Choosing by relation
        # size alone can pick an unconstrained level-0 iterator and blow the
        # frontier up by the whole value domain (§Perf join iteration log).
        self.at_depth: List[List[Tuple[int, int]]] = []
        self.guard: List[int] = []
        for x in self.order:
            parts = [(ai, self.atom_vars[ai].index(x))
                     for ai in range(self.m) if x in self.atom_vars[ai]]
            assert parts, f"variable {x} not covered"
            self.at_depth.append(parts)
            scores = [lvl * (1 << 40) - self.sizes[ai] for ai, lvl in parts]
            self.guard.append(int(np.argmax(scores)))
        self._expand_jits: Dict[int, object] = {}
        # vanilla LFTJ lowers to the trivial schedule: EXPAND over every
        # depth, then EMIT (subclasses re-lower with their TD plan)
        self.schedule = lower(self.n)

    # ------------------------------------------------------------------
    def initial_frontier(self) -> Frontier:
        C, n, m = self.capacity, self.n, self.m
        lo = jnp.zeros((C, m), jnp.int32)
        hi = jnp.zeros((C, m), jnp.int32).at[0, :].set(
            jnp.asarray(self.sizes, jnp.int32))
        return Frontier(
            assign=jnp.zeros((C, n), jnp.int32),
            factor=jnp.zeros((C,), jnp.int64).at[0].set(1),
            valid=jnp.zeros((C,), bool).at[0].set(True),
            orig=jnp.zeros((C,), jnp.int32),
            lo=lo, hi=hi)

    # ------------------------------------------------------------------
    def _expand_fn(self, d: int):
        """Return the registry-dispatched expansion step for depth d
        (fused Pallas or the XLA chain, per ``expand_kernel`` — the
        chosen path is recorded in ``expand_paths[d]``).  The XLA step
        stays module-level jitted in ``kernels/expand/xla.py`` so its
        jit cache is shared across engine instances."""
        if d in self._expand_jits:
            return self._expand_jits[d]
        args = self.expand_kernel_args(d)
        spec = kernels.ExpandSpec(
            capacity=self.capacity, n_vars=self.n, n_atoms=self.m,
            n_others=len(args["other_ais"]),
            dtype=str(args["g_col"].dtype),
            x64=bool(jax.config.jax_enable_x64))
        fn, chosen = kernels.expand_fn(
            spec, mode=self.expand_kernel, impl=self.impl,
            sizes=self.sizes, **args)
        self.expand_paths[d] = chosen
        self._expand_jits[d] = fn
        return fn

    def expand_kernel_args(self, d: int) -> Dict:
        """The per-depth kernel-builder arguments derived from the
        columnar tries (the single source the registry, tests, and
        benchmarks build EXPAND(d) kernels from)."""
        parts = self.at_depth[d]
        gi = self.guard[d]
        g_ai, g_lvl = parts[gi]
        g = self.levels[g_ai][g_lvl]
        others = tuple((ai, lvl) for k, (ai, lvl) in enumerate(parts)
                       if k != gi)
        return dict(d=d, g_ai=g_ai,
                    other_ais=tuple(ai for ai, _ in others),
                    g_col=g.col, g_rs=g.runstarts,
                    other_cols=tuple(self.levels[ai][lvl].col
                                     for ai, lvl in others),
                    n_rows_g=self.sizes[g_ai])

    def expand_impl(self, d: int) -> str:
        """Which kernel path EXPAND(d) runs on ("pallas" | "xla")."""
        self._expand_fn(d)
        return self.expand_paths[d]

    def expand_call_counts(self) -> Dict[str, int]:
        """Per-path EXPAND chunk-launch counts of the last execution."""
        ex = getattr(self, "last_executor", None)
        if ex is None:
            return {}
        return dict(ex.expand_path_runs)

    # ------------------------------------------------------------------
    def expand_plan(self, d: int) -> Tuple[int, np.ndarray, int]:
        """Host-side planning arrays for depth d's guard: the executor
        fetches (lo, hi, valid) once per op and derives candidate counts
        for morsel admission/splitting from these."""
        parts = self.at_depth[d]
        g_ai, g_lvl = parts[self.guard[d]]
        return g_ai, self.levels[g_ai][g_lvl].runstarts_np, self.sizes[g_ai]

    def split_chunk_host(self, host: Dict[str, np.ndarray], d: int,
                         counts: np.ndarray) -> List[Frontier]:
        """Split a chunk whose expansion would overflow capacity.

        ``host`` is the chunk already fetched to host (one batched sync by
        the executor).  Rows are greedily packed into pieces whose total
        candidate count fits; a single oversized row is split by guard
        *run ranges*, so each piece enumerates a disjoint slice of its
        candidate values.
        """
        C = self.capacity
        g_ai, rs, n_rows_g = self.expand_plan(d)
        rows: List[Dict[str, np.ndarray]] = []
        for i in np.flatnonzero(host["valid"]):
            c = int(counts[i])
            if c <= C:
                rows.append({k: v[i] for k, v in host.items()})
                continue
            # oversized: split the guard run range
            lo_i, hi_i = int(host["lo"][i, g_ai]), int(host["hi"][i, g_ai])
            r0 = int(np.searchsorted(rs, lo_i, side="left"))
            r1 = int(np.searchsorted(rs, hi_i, side="left"))
            for a in range(r0, r1, C):
                b = min(a + C, r1)
                piece = {k: v[i].copy() for k, v in host.items()}
                piece["lo"] = piece["lo"].copy()
                piece["hi"] = piece["hi"].copy()
                piece["lo"][g_ai] = rs[a]
                piece["hi"][g_ai] = rs[b] if b < len(rs) else n_rows_g
                rows.append(piece)
        # greedy pack rows into pieces
        pieces: List[Frontier] = []
        cur: List[Dict[str, np.ndarray]] = []
        cur_count = 0

        def flush():
            nonlocal cur, cur_count
            if not cur:
                return
            pieces.append(self._pack_rows(cur))
            cur, cur_count = [], 0

        for r in rows:
            lo_r, hi_r = int(r["lo"][g_ai]), int(r["hi"][g_ai])
            c = int(np.searchsorted(rs, hi_r) - np.searchsorted(rs, lo_r))
            if cur and (cur_count + c > C or len(cur) == C):
                flush()
            cur.append(r)
            cur_count += c
        flush()
        return pieces

    def _pack_rows(self, rows: List[Dict[str, np.ndarray]]) -> Frontier:
        C = self.capacity
        out = {}
        for k in Frontier._fields:
            proto = rows[0][k]
            arr = np.zeros((C,) + proto.shape, dtype=proto.dtype)
            for i, r in enumerate(rows):
                arr[i] = r[k]
            out[k] = jnp.asarray(arr)
        out["valid"] = jnp.asarray(
            np.arange(C) < len(rows)) & out["valid"].astype(bool)
        return Frontier(**out)

    # ------------------------------------------------------------------
    def count(self) -> int:
        with enable_x64():
            ex = ScheduleExecutor(self, mode="count")
            self.last_executor = ex  # op_runs / sync diagnostics
            return ex.count()

    def evaluate(self) -> Iterator[np.ndarray]:
        """Yields (k, n) blocks of result assignments (order columns)."""
        with enable_x64():
            ex = ScheduleExecutor(self, mode="evaluate")
            self.last_executor = ex
            yield from ex.evaluate()

    def evaluate_stream(self) -> Iterator[np.ndarray]:
        """Streaming evaluation: the same blocks as :meth:`evaluate`, in
        the same order, with each block's device→host copy issued
        asynchronously as the block is produced (bounded by
        ``emit_in_flight``; DESIGN.md §2.8)."""
        with enable_x64():
            ex = ScheduleExecutor(self, mode="evaluate")
            self.last_executor = ex
            yield from ex.evaluate_stream()


def jax_lftj_count(q: CQ, order: Sequence[str], db: Database,
                   capacity: int = 1 << 17, impl: str = "bsearch",
                   expand_kernel: str = "auto") -> int:
    return JaxTrieJoin(q, order, db, capacity=capacity, impl=impl,
                       expand_kernel=expand_kernel).count()


def jax_lftj_evaluate(q: CQ, order: Sequence[str], db: Database,
                      capacity: int = 1 << 17, impl: str = "bsearch",
                      expand_kernel: str = "auto") -> np.ndarray:
    eng = JaxTrieJoin(q, order, db, capacity=capacity, impl=impl,
                      expand_kernel=expand_kernel)
    blocks = list(eng.evaluate())
    if not blocks:
        return np.zeros((0, len(eng.order)), np.int32)
    return np.concatenate(blocks, axis=0)

"""Databases of integer relations + execution counters.

Relations are numpy ``(N, k)`` int64 matrices (deduplicated).  The counters
implement the paper's "memory accesses" analysis (§1): every trie probe is a
binary-search (log-many accesses) and every scanned value is one access.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np


class JoinBudgetExceeded(RuntimeError):
    """Raised when an engine exceeds its memory-access budget (the
    benchmark-harness analogue of the paper's 10-hour timeout)."""


@dataclass
class Counters:
    """Memory-access proxy counters, shared by all engines."""

    seeks: int = 0              # binary searches issued
    mem_accesses: int = 0       # weighted access proxy (log2 per seek, 1/scan)
    values_scanned: int = 0     # trie values materialized/visited
    tuples_emitted: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_inserts: int = 0
    cache_skipped: int = 0      # admissions declined by policy/capacity
    intermediate_tuples: int = 0  # YTD: materialized intermediate tuples
    hash_probes: int = 0
    budget: Optional[int] = None  # mem-access cap; exceeding raises

    def _check(self) -> None:
        if self.budget is not None and self.mem_accesses > self.budget:
            raise JoinBudgetExceeded(f"budget {self.budget} exceeded")

    def count_seek(self, n: int) -> None:
        self.seeks += 1
        self.mem_accesses += max(1, int(math.ceil(math.log2(max(2, n)))))
        self._check()

    def count_scan(self, n: int = 1) -> None:
        self.values_scanned += n
        self.mem_accesses += n
        self._check()

    def count_hash(self, n: int = 1) -> None:
        self.hash_probes += n
        self.mem_accesses += n
        self._check()

    def snapshot(self) -> Dict[str, int]:
        d = dict(self.__dict__)
        d.pop("budget", None)
        return d


def _canonical(rows: np.ndarray) -> np.ndarray:
    """Deduplicate + lexicographically sort rows (leftmost column primary)."""
    rows = np.asarray(rows, dtype=np.int64)
    if rows.ndim != 2:
        raise ValueError("relation must be (N, k)")
    if rows.shape[0] == 0:
        return rows
    rows = np.unique(rows, axis=0)  # unique sorts lexicographically by rows
    return rows


class Database:
    """name -> (N, k) relation; caches per-column-permutation sorted copies."""

    def __init__(self, relations: Dict[str, np.ndarray]):
        self.relations: Dict[str, np.ndarray] = {
            name: _canonical(arr) for name, arr in relations.items()}
        self._sorted_cache: Dict[Tuple[str, Tuple[int, ...]], np.ndarray] = {}

    def arity(self, name: str) -> int:
        return self.relations[name].shape[1]

    def size(self, name: str) -> int:
        return self.relations[name].shape[0]

    def sorted_view(self, name: str, perm: Sequence[int]) -> np.ndarray:
        """Rows with columns permuted by ``perm``, lex-sorted (a trie view)."""
        key = (name, tuple(perm))
        if key not in self._sorted_cache:
            rows = self.relations[name][:, list(perm)]
            self._sorted_cache[key] = _canonical(rows)
        return self._sorted_cache[key]

    def stats(self):
        from .decompose import DBStats
        tuples = {n: r.shape[0] for n, r in self.relations.items()}
        distinct = {}
        for n, r in self.relations.items():
            for c in range(r.shape[1]):
                distinct[(n, c)] = int(np.unique(r[:, c]).size)
        return DBStats(tuples=tuples, distinct=distinct)


def graph_db(edges: np.ndarray, name: str = "E",
             symmetrize: bool = False) -> Database:
    edges = np.asarray(edges, dtype=np.int64)
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    # drop self loops, in line with the paper's graph workloads
    edges = edges[edges[:, 0] != edges[:, 1]]
    return Database({name: edges})

"""GenericDecompose (paper Fig 4) and TD enumeration / selection (§4).

``RecursiveTD(g, C)`` consumes a solver for the side-constrained graph
separation problem and returns an ordered TD whose root bag contains C.  The
enumeration variant replaces the single ConstrainedSep call with the ranked
separator enumeration of ``separators.py`` (by increasing size), explores a
bounded number of choices per call, and scores the resulting TDs with the
§4.3 heuristic (small adhesions, many bags, low depth, Chu-style cost).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, FrozenSet, Iterator, List, Optional, Sequence, Set, Tuple

from .cq import CQ
from .gaifman import (Graph, connected_components, gaifman_graph,
                      induced_subgraph, remove_nodes)
from .separators import enumerate_constrained_separators
from .td import TreeDecomposition, singleton_td

# A ConstrainedSep solver returns (S, U) per the paper's convention, or None.
SepChoice = Tuple[FrozenSet[str], FrozenSet[str]]
SepSolver = Callable[[Graph, Set[str]], Optional[SepChoice]]


def _split(g: Graph, C: Set[str], S: FrozenSet[str]) -> SepChoice:
    """Compute U = union of components of g-S intersecting C (paper §4.1);
    if none intersects C, U is the first component (deterministic)."""
    comps = connected_components(remove_nodes(g, S))
    touching = [c for c in comps if c & C]
    U = set().union(*touching) if touching else set(comps[0])
    return S, frozenset(U)


def first_separator_solver(max_adhesion: Optional[int] = None) -> SepSolver:
    """ConstrainedSep = the smallest C-constrained separating set."""

    def solver(g: Graph, C: Set[str]) -> Optional[SepChoice]:
        for S in enumerate_constrained_separators(g, C, max_size=max_adhesion,
                                                  max_results=1):
            return _split(g, C, S)
        return None

    return solver


# ---------------------------------------------------------------------------
# RecursiveTD (paper Fig 4)
# ---------------------------------------------------------------------------

def recursive_td(g: Graph, C: Set[str], solver: SepSolver) -> TreeDecomposition:
    res = solver(g, C)
    if res is None:
        return singleton_td(sorted(g))
    S, U = res
    # line 4: TD of g[S ∪ U] whose root bag contains C ∪ S
    td0 = recursive_td(induced_subgraph(g, S | U), C | set(S), solver)
    parts: List[TreeDecomposition] = [td0]
    for Vi in connected_components(remove_nodes(g, S | U)):
        parts.append(recursive_td(induced_subgraph(g, set(S) | Vi), set(S), solver))
    return _graft(parts)


def _graft(parts: Sequence[TreeDecomposition]) -> TreeDecomposition:
    """Connect roots of parts[1:] as children of parts[0]'s root (Fig 4 l.8)."""
    bags: List[FrozenSet[str]] = []
    parent: List[int] = []
    offsets = []
    for td in parts:
        offsets.append(len(bags))
        base = len(bags)
        for v in range(td.num_nodes):
            bags.append(td.bags[v])
            parent.append(td.parent[v] + base if td.parent[v] >= 0 else -2)
        parent[base + td.root] = -2  # placeholder
    root0 = offsets[0] + parts[0].root
    for i, td in enumerate(parts):
        r = offsets[i] + td.root
        parent[r] = -1 if i == 0 else root0
    # fix placeholders for non-root roots already set; roots of parts>0 point
    # at root0, root of part 0 is the global root.
    for i in range(len(parent)):
        if parent[i] == -2:
            parent[i] = -1
    return TreeDecomposition(bags, parent)


def generic_decompose(q: CQ, solver: Optional[SepSolver] = None,
                      simplify: bool = True) -> TreeDecomposition:
    """Paper Fig 4's GenericDecompose: one ordered TD of q."""
    g = gaifman_graph(q)
    td = recursive_td(g, set(), solver or first_separator_solver())
    if simplify:
        td = td.eliminate_redundant_bags()
    td.validate(q)
    return td


# ---------------------------------------------------------------------------
# Enumeration of TDs (paper §4.2-4.3)
# ---------------------------------------------------------------------------

def enumerate_tds(q: CQ, max_adhesion: int = 2, per_step: int = 3,
                  limit: int = 32, simplify: bool = True,
                  ) -> List[TreeDecomposition]:
    """Enumerate TDs by branching RecursiveTD over the ``per_step`` smallest
    C-constrained separators at every call (paper: "replace line 1 with a
    procedure that efficiently enumerates C-constrained separating sets").

    Deduplicates by canonical signature.  Bounded by ``limit`` TDs.
    """
    g0 = gaifman_graph(q)
    out: List[TreeDecomposition] = []
    seen: Set[Tuple] = set()

    def rec(g: Graph, C: Set[str]) -> Iterator[TreeDecomposition]:
        found = False
        for S in enumerate_constrained_separators(
                g, C, max_size=max_adhesion, max_results=per_step):
            found = True
            S, U = _split(g, C, S)
            sub0 = list(itertools.islice(rec(induced_subgraph(g, set(S) | set(U)),
                                             C | set(S)), per_step))
            rest = connected_components(remove_nodes(g, set(S) | set(U)))
            subs_per_comp = [
                list(itertools.islice(rec(induced_subgraph(g, set(S) | Vi),
                                          set(S)), per_step))
                for Vi in rest]
            for combo in itertools.islice(
                    itertools.product(sub0, *subs_per_comp), per_step):
                yield _graft(list(combo))
        if not found:
            yield singleton_td(sorted(g))

    for td in rec(g0, set()):
        if simplify:
            td = td.eliminate_redundant_bags()
        td.validate(q)
        sig = _signature(td)
        if sig not in seen:
            seen.add(sig)
            out.append(td)
        if len(out) >= limit:
            break
    return out


def _signature(td: TreeDecomposition) -> Tuple:
    bags = tuple(sorted(tuple(sorted(b)) for b in td.bags))
    edges = tuple(sorted(
        (tuple(sorted(td.bags[v])), tuple(sorted(td.bags[td.parent[v]])))
        for v in range(td.num_nodes) if td.parent[v] >= 0))
    return bags, edges


# ---------------------------------------------------------------------------
# Cost heuristics (paper §4.3) and plan selection
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DBStats:
    """Cardinality statistics used by the Chu-et-al-style cost estimate."""

    tuples: Dict[str, int]            # relation -> |R|
    distinct: Dict[Tuple[str, int], int]  # (relation, column) -> #distinct


def td_heuristic_key(td: TreeDecomposition) -> Tuple:
    """§4.3: small max adhesion first, then many bags, then low depth."""
    return (td.max_adhesion_size(), -td.num_nodes, td.depth(), td.width())


def order_cost(q: CQ, order: Sequence[str], stats: Optional[DBStats]) -> float:
    """A Chu-et-al-flavoured cost estimate for a variable ordering: walk the
    order and multiply an expected blow-up per variable, derived from
    per-relation selectivities (|R| / prod(distinct)).  Coarse, monotone in
    the right things (constraining early variables with selective atoms is
    cheap); used only to rank orders/TDs.
    """
    if stats is None:
        return 0.0
    bound: Set[str] = set()
    cost = 0.0
    size = 1.0
    for x in order:
        # candidate growth: min over atoms covering x of expected extensions
        growth = None
        for atom in q.atoms_with(x):
            nbound = sum(1 for v in atom.vars if v in bound)
            n = stats.tuples.get(atom.relation, 1)
            d = 1.0
            for i, v in enumerate(atom.vars):
                if v in bound:
                    d *= max(1, stats.distinct.get((atom.relation, i), 1))
            est = max(1.0, n / d)
            growth = est if growth is None else min(growth, est)
        growth = growth if growth is not None else 1.0
        size *= growth
        cost += size
        bound.add(x)
    return cost


def choose_plan(q: CQ, stats: Optional[DBStats] = None,
                max_adhesion: int = 2, limit: int = 24,
                ) -> Tuple[TreeDecomposition, Tuple[str, ...]]:
    """Enumerate TDs, rank by (§4.3 heuristic, order cost), return the best
    TD plus a strongly compatible variable ordering."""
    tds = enumerate_tds(q, max_adhesion=max_adhesion, limit=limit)
    best = None
    for td in tds:
        order = td.strongly_compatible_order()
        key = (td_heuristic_key(td), order_cost(q, order, stats))
        if best is None or key < best[0]:
            best = (key, td, order)
    assert best is not None
    _, td, order = best
    return td, order

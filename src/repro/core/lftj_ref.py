"""Vanilla LFTJ — the paper's Figure 1 (TJCount) plus evaluation mode.

Reference (host, numpy-backed) implementation; the JAX engine in
``frontier.py`` is validated against it.  Instrumented with the memory-access
proxy counters used for the paper's §1 analysis.
"""
from __future__ import annotations

import sys
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .cq import CQ
from .db import Counters, Database
from .trie import AtomTrie, leapfrog_intersection


class LFTJ:
    """Trie join over a fixed variable order (paper Fig 1 abstraction)."""

    def __init__(self, q: CQ, order: Sequence[str], db: Database,
                 counters: Optional[Counters] = None):
        self.q = q
        self.order = tuple(order)
        if sorted(self.order) != sorted(q.variables):
            raise ValueError("order must permute vars(q)")
        self.db = db
        self.counters = counters if counters is not None else Counters()
        self.tries = [AtomTrie.build(db, a.relation, a.vars, self.order)
                      for a in q.atoms]
        # per depth d: list of (atom index, trie level) of atoms binding x_d
        self.at_depth: List[List[Tuple[int, int]]] = []
        for x in self.order:
            participants = []
            for ai, at in enumerate(self.tries):
                if x in at.var_order:
                    participants.append((ai, at.level_of(x)))
            self.at_depth.append(participants)

    # -- execution ---------------------------------------------------------
    def count(self) -> int:
        total = 0
        for _ in self._scan(emit=False):
            total += 1
        return total

    def evaluate(self) -> Iterator[Tuple[int, ...]]:
        """Yields assignments as tuples in variable order."""
        yield from self._scan(emit=True)

    def _scan(self, emit: bool) -> Iterator[Tuple[int, ...]]:
        n = len(self.order)
        mu: List[int] = [0] * n
        ranges: List[Dict[int, Tuple[int, int]]] = [dict() for _ in range(n + 1)]
        ranges[0] = {ai: at.trie.full_range() for ai, at in enumerate(self.tries)}
        sys.setrecursionlimit(10_000)

        def rjoin(d: int) -> Iterator[Tuple[int, ...]]:
            if d == n:
                self.counters.tuples_emitted += 1
                yield tuple(mu)
                return
            parts = self.at_depth[d]
            iters = [(self.tries[ai].trie, lvl, *ranges[d][ai])
                     for ai, lvl in parts]
            for a, sub in leapfrog_intersection(iters, self.counters):
                mu[d] = a
                nxt = dict(ranges[d])
                for (ai, _lvl), (s, e) in zip(parts, sub):
                    nxt[ai] = (s, e)
                ranges[d + 1] = nxt
                yield from rjoin(d + 1)

        yield from rjoin(0)


def lftj_count(q: CQ, order: Sequence[str], db: Database,
               counters: Optional[Counters] = None) -> int:
    return LFTJ(q, order, db, counters).count()


def lftj_evaluate(q: CQ, order: Sequence[str], db: Database,
                  counters: Optional[Counters] = None,
                  ) -> List[Tuple[int, ...]]:
    return list(LFTJ(q, order, db, counters).evaluate())

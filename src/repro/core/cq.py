"""Conjunctive queries (full CQs, no projection) — paper §2.2.

A full CQ is a sequence of subgoals ``R(t1..tk)``; here terms are variable
names (strings). Constants are supported by pre-filtering relations, which is
how every system in the paper's experimental section handles them, so the core
engine only sees variables.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple


@dataclass(frozen=True)
class Atom:
    """One subgoal R(x1..xk).  ``relation`` names the relation in the DB."""

    relation: str
    vars: Tuple[str, ...]

    def __post_init__(self):
        if len(self.vars) == 0:
            raise ValueError("nullary atoms are not supported")

    @property
    def arity(self) -> int:
        return len(self.vars)

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return f"{self.relation}({', '.join(self.vars)})"


@dataclass(frozen=True)
class CQ:
    """A full conjunctive query: a tuple of atoms."""

    atoms: Tuple[Atom, ...]

    def __post_init__(self):
        if not self.atoms:
            raise ValueError("empty query")

    @property
    def variables(self) -> Tuple[str, ...]:
        """All variables, in first-occurrence order (deterministic)."""
        seen: Dict[str, None] = {}
        for a in self.atoms:
            for v in a.vars:
                seen.setdefault(v)
        return tuple(seen)

    def atoms_with(self, var: str) -> Tuple[Atom, ...]:
        return tuple(a for a in self.atoms if var in a.vars)

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return ", ".join(str(a) for a in self.atoms)


def cq(*specs: Tuple[str, Sequence[str]]) -> CQ:
    """Convenience constructor: ``cq(("E", "ab"), ("E", "bc"))``."""
    return CQ(tuple(Atom(rel, tuple(vs)) for rel, vs in specs))


# ---------------------------------------------------------------------------
# Query families used throughout the paper's experiments (§5.2.2)
# ---------------------------------------------------------------------------

def _vname(i: int) -> str:
    return f"x{i}"


def path_query(length: int, relation: str = "E") -> CQ:
    """k-path: E(x1,x2), E(x2,x3), ..., E(xk, x{k+1}).

    The paper's "k-path" has k edges (a 4-path comprises E(a,b),E(b,c),E(c,d)
    — the paper's example shows 3 atoms for a 4-path, i.e. k-1 edges over k
    nodes; we follow *edges = length - 1* to match: a valid 4-path comprises
    three atoms)."""
    if length < 2:
        raise ValueError("path needs >= 2 nodes")
    return CQ(tuple(Atom(relation, (_vname(i), _vname(i + 1)))
                    for i in range(1, length)))


def cycle_query(length: int, relation: str = "E") -> CQ:
    """k-cycle: E(x1,x2), ..., E(x{k-1},xk), E(x1,xk) — paper §5.2.2."""
    if length < 3:
        raise ValueError("cycle needs >= 3 nodes")
    atoms = [Atom(relation, (_vname(i), _vname(i + 1))) for i in range(1, length)]
    atoms.append(Atom(relation, (_vname(1), _vname(length))))
    return CQ(tuple(atoms))


def clique_query(size: int, relation: str = "E") -> CQ:
    """k-clique — included because the paper *discusses* cliques (no TD)."""
    if size < 2:
        raise ValueError("clique needs >= 2 nodes")
    atoms = [Atom(relation, (_vname(i), _vname(j)))
             for i in range(1, size) for j in range(i + 1, size + 1)]
    return CQ(tuple(atoms))


def lollipop_query(clique_size: int = 3, tail_len: int = 2,
                   relation: str = "E") -> CQ:
    """{clique_size, tail_len}-lollipop (paper Fig 12: {3,2}-lollipop).

    A clique on x1..xc plus a path of ``tail_len`` extra edges hanging off xc.
    """
    atoms = [Atom(relation, (_vname(i), _vname(j)))
             for i in range(1, clique_size) for j in range(i + 1, clique_size + 1)]
    for i in range(clique_size, clique_size + tail_len):
        atoms.append(Atom(relation, (_vname(i), _vname(i + 1))))
    return CQ(tuple(atoms))


def bowtie_query(relation: str = "E") -> CQ:
    """Bowtie: two triangles sharing the hub x1 — a TD with two recurring
    bags keyed on the same hub variable (the evaluation-mode row-block
    cache's clique-style workload)."""
    return CQ((Atom(relation, ("x1", "x2")), Atom(relation, ("x2", "x3")),
               Atom(relation, ("x1", "x3")), Atom(relation, ("x1", "x4")),
               Atom(relation, ("x4", "x5")), Atom(relation, ("x1", "x5"))))


def star_query(rays: int, relation: str = "E") -> CQ:
    """k-star: E(x1,x2), E(x1,x3), ..., E(x1,x{k+1}) — hub x1, k rays.

    Acyclic with singleton adhesions ({x1}); the extreme cache-friendly
    shape (every ray subtree keys on the hub value alone)."""
    if rays < 1:
        raise ValueError("star needs >= 1 ray")
    return CQ(tuple(Atom(relation, (_vname(1), _vname(i + 2)))
                    for i in range(rays)))


def random_graph_query(n: int, p: float, seed: int,
                       relation: str = "E") -> CQ:
    """Erdős–Rényi query graph, connected, no self edges (paper §5.2.2).

    Deterministic for a given (n, p, seed); resamples until connected.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    for _attempt in range(10_000):
        edges = [(i, j) for i in range(1, n) for j in range(i + 1, n + 1)
                 if rng.random() < p]
        if not edges:
            continue
        # connectivity check (union-find)
        parent = list(range(n + 1))

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for i, j in edges:
            parent[find(i)] = find(j)
        if len({find(i) for i in range(1, n + 1)}) == 1:
            return CQ(tuple(Atom(relation, (_vname(i), _vname(j)))
                            for i, j in edges))
    raise RuntimeError("could not sample a connected graph")


def two_relation_cycle_query(length: int, relations: Sequence[str]) -> CQ:
    """Cycle alternating over the given relation names (IMDB-style 4/6-cycle
    over male_cast/female_cast, paper Fig 14)."""
    if length < 3:
        raise ValueError("cycle needs >= 3 nodes")
    atoms = []
    for i in range(1, length):
        atoms.append(Atom(relations[(i - 1) % len(relations)],
                          (_vname(i), _vname(i + 1))))
    atoms.append(Atom(relations[(length - 1) % len(relations)],
                      (_vname(1), _vname(length))))
    return CQ(tuple(atoms))

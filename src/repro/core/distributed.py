"""Distributed CLFTJ: a fully-jittable static pipeline + mesh execution.

The host-driven engine (``cached_frontier``) splits morsels adaptively; for
SPMD execution we instead fix the chunk capacity, interpret the lowered op
schedule at trace time (``schedule.execute_static``), and flag overflow
instead of splitting.  The result is one pure function
(frontier₀, cache tables) → (count, overflow, tables) that
``shard_map``s across the mesh: each shard owns a contiguous slice of the
top-level variable's candidate runs (the natural LFTJ work partition — see
DESIGN.md §3), keeps a private cache (caching is an optimization, never a
correctness requirement, so no coherence traffic), and the only collective
is the final count psum.

Evaluation (DESIGN.md §2.8) runs the same pure schedule in materialization
mode with **payload-capable** tier-2 tables: each shard keeps a private
slab arena (the §2.6 row-block region, bump pointer threaded as a traced
scalar), splices its own payload hits shard-locally, and returns its
result chunk; the host merges the per-shard ``(assign, valid)`` blocks —
no result collective.  Tables round-trip through
:func:`make_distributed_evaluate`'s returned callable, so a second pass
over the same (or an overlapping) workload serves tier-2 replay hits.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .cache import CacheConfig
from .cached_frontier import JaxCachedTrieJoin, _resolve_cache_config
from .cq import CQ
from .db import Database
from .frontier import Frontier
from .hostsync import device_get
from .schedule import FOLD_CHILD, execute_static
from .td import TreeDecomposition


class StaticCLFTJ(JaxCachedTrieJoin):
    """Jittable fixed-capacity CLFTJ (no host-side morsel splitting).

    Tier-2 tables are (S, W) arrays per the configured :class:`CacheConfig`
    policy; each shard keeps a private table (no coherence traffic) and the
    LRU tick is a static counter baked in by the unrolled op schedule —
    the *same* lowered schedule the host executor interprets, run through
    ``schedule.execute_static`` instead of a third recursion copy."""

    # -----------------------------------------------------------------
    def make_tables(self, mode: str = "count") -> Dict[int, tuple]:
        """Fresh functional tier-2 tables for every probed TD node: the
        count-only 5-tuple, or — ``mode="evaluate"`` with
        ``cache_payloads`` — the 9-tuple with the §2.6 payload region
        (metadata planes, slab arena sized to the node's subtree width,
        traced bump pointer)."""
        cfg = self.cache_config
        if cfg.initial_slots() <= 0:
            return {}
        w = cfg.ways
        s = max(1, cfg.initial_slots() // w)
        tables: Dict[int, tuple] = {}
        for op in self.schedule.ops:
            if op.kind != FOLD_CHILD or not op.probe or op.node in tables:
                continue
            base = (jnp.zeros((s, w), jnp.int64),
                    jnp.zeros((s, w), jnp.int64),
                    jnp.zeros((s, w), bool),
                    jnp.zeros((s, w), jnp.int32),
                    jnp.zeros((s, w), jnp.int64))
            if mode == "evaluate" and cfg.cache_payloads:
                width = op.sub_last - op.sub_first + 1
                tables[op.node] = base + (
                    jnp.zeros((s, w), jnp.int32),
                    jnp.full((s, w), -1, jnp.int32),
                    jnp.zeros((int(cfg.payload_rows) + 1, width),
                              jnp.int32),
                    jnp.zeros((), jnp.int32))
            else:
                tables[op.node] = base
        return tables

    def count_fn(self):
        """Returns a pure fn(frontier0) -> (count, overflow)."""
        cfg = self.cache_config

        def fn(F0: Frontier):
            total, ov, _ = execute_static(self.schedule, self, F0,
                                          self.make_tables("count"), cfg)
            return total, ov

        return fn

    def evaluate_fn(self):
        """Returns a pure fn(frontier0, tables) -> (assign, valid, count,
        overflow, replay_hits, tables) — the payload-capable trace-time
        evaluation of the lowered schedule (DESIGN.md §2.8)."""
        cfg = self.cache_config

        def fn(F0: Frontier, tables: Dict[int, tuple]):
            return execute_static(self.schedule, self, F0, tables, cfg,
                                  mode="evaluate")

        return fn

    def evaluate_static(self, tables: Optional[Dict[int, tuple]] = None):
        """Single-device trace-time evaluation with tier-2 payloads.

        Returns ``(rows, stats, tables)`` — rows the materialized (N, n)
        int32 result, ``stats`` with ``count``/``overflow``/
        ``tier2_replay_hits``, and the updated functional tables to pass
        back in for a warm pass (recurring adhesion keys then splice from
        the slab instead of re-expanding)."""
        with enable_x64():
            if tables is None:
                tables = self.make_tables("evaluate")
            F0 = self.initial_frontier()
            assign, valid, total, ov, hits, tables = self.evaluate_fn()(
                F0, tables)
            a, v, t, o, h = device_get((assign, valid, total, ov, hits),
                                       "static-eval")
        rows = np.asarray(a)[np.asarray(v)]
        stats = {"count": int(t), "overflow": bool(o),
                 "tier2_replay_hits": int(h)}
        return rows, stats, tables


class _GuardPartition:
    """The top-level work partition shared by every distributed entry
    point: shard i of D takes guard runs [i·R/D, (i+1)·R/D) — the lo/hi
    math must stay byte-identical between count and evaluate, or the two
    would shard different row ranges."""

    def __init__(self, eng: StaticCLFTJ, mesh: Mesh,
                 axes: Tuple[str, ...]):
        self.eng = eng
        self.mesh = mesh
        g_ai, g_lvl = eng.at_depth[0][eng.guard[0]]
        self.g_ai = g_ai
        self.rs = eng.levels[g_ai][g_lvl].runstarts
        self.nruns = self.rs.shape[0]
        self.n_rows_g = eng.sizes[g_ai]
        self.all_axes = tuple(a for a in axes if a in mesh.axis_names)
        self.d_total = int(np.prod([mesh.shape[a] for a in self.all_axes]))

    def shard_frontier(self) -> Frontier:
        """This shard's initial frontier (call inside the shard body)."""
        idx = jnp.zeros((), jnp.int32)
        mult = 1
        for a in reversed(self.all_axes):
            idx = idx + jax.lax.axis_index(a) * mult
            mult *= self.mesh.shape[a]
        r0 = (idx * self.nruns) // self.d_total
        r1 = ((idx + 1) * self.nruns) // self.d_total
        lo0 = jnp.where(r0 < self.nruns,
                        self.rs[jnp.clip(r0, 0, self.nruns - 1)],
                        self.n_rows_g).astype(jnp.int32)
        hi0 = jnp.where(r1 < self.nruns,
                        self.rs[jnp.clip(r1, 0, self.nruns - 1)],
                        self.n_rows_g).astype(jnp.int32)
        F0 = self.eng.initial_frontier()
        return F0._replace(lo=F0.lo.at[0, self.g_ai].set(lo0),
                           hi=F0.hi.at[0, self.g_ai].set(hi0))


def make_distributed_count(q: CQ, td: TreeDecomposition,
                           order: Sequence[str], db: Database, mesh: Mesh,
                           capacity: int = 1 << 14,
                           axes: Tuple[str, ...] = ("data",),
                           cache: Optional[CacheConfig] = None,
                           expand_kernel: str = "auto"):
    """Build (jitted_fn, engine).  ``jitted_fn()`` -> (count, overflow).

    Work partition: shard i of D takes top-level guard runs
    [i·R/D, (i+1)·R/D); relations are replicated (closure constants); the
    final count is a psum over the mesh axes — the single collective.
    ``expand_kernel`` is resolved per spec at trace time (the registry
    choice is baked into the unrolled schedule, identically per shard).
    """
    cache = _resolve_cache_config(cache, None, default_slots=1 << 15)
    eng = StaticCLFTJ(q, td, order, db, capacity=capacity, cache=cache,
                      expand_kernel=expand_kernel)
    part = _GuardPartition(eng, mesh, axes)
    count_fn = eng.count_fn()

    def per_shard():
        with enable_x64():
            total, ov = count_fn(part.shard_frontier())
            total = jax.lax.psum(total, part.all_axes)
            ov = jax.lax.psum(ov.astype(jnp.int32), part.all_axes)
            return total, ov

    fn = shard_map(per_shard, mesh=mesh, in_specs=(),
                   out_specs=(P(), P()), check_rep=False)
    return _X64Jit(fn), eng


def make_distributed_evaluate(q: CQ, td: TreeDecomposition,
                              order: Sequence[str], db: Database, mesh: Mesh,
                              capacity: int = 1 << 14,
                              axes: Tuple[str, ...] = ("data",),
                              cache: Optional[CacheConfig] = None,
                              expand_kernel: str = "auto"):
    """Build (eval_fn, engine) for payload-capable distributed evaluation.

    ``eval_fn(tables=None)`` runs one materialization pass over the mesh
    and returns ``(rows, stats, tables)``: each shard evaluates its guard-
    run slice through the static schedule with a *private* payload-capable
    tier-2 table + slab arena (shard-local splice, no coherence traffic),
    the host concatenates the per-shard ``(assign, valid)`` result chunks
    (the host-side merge — there is no result collective; count/overflow/
    replay-hit scalars are the only psums).  Tables are stacked on a
    leading shard axis and round-trip: pass the returned ``tables`` back
    in and recurring adhesion keys are served by slab splice
    (``stats["tier2_replay_hits"] > 0``) instead of re-expansion.
    Replay requires ``cache_payloads=True`` — the default here (unlike
    the count factory): an explicit payloads-off config still evaluates
    exactly, but its tables are count-only and every probe misses.
    """
    if cache is None:
        cache = CacheConfig(policy="direct", slots=1 << 15,
                            cache_payloads=True)
    cache = _resolve_cache_config(cache, None, default_slots=1 << 15)
    eng = StaticCLFTJ(q, td, order, db, capacity=capacity, cache=cache,
                      expand_kernel=expand_kernel)
    part = _GuardPartition(eng, mesh, axes)
    d_total = part.d_total
    eval_fn = eng.evaluate_fn()
    spec = P(part.all_axes)
    with enable_x64():
        template = eng.make_tables("evaluate")
    table_specs = jax.tree.map(lambda _: spec, template)

    def init_tables():
        with enable_x64():
            # stack the spec template itself — building a second full
            # table set (slab arenas included) just to throw it away
            # would double the allocation per factory call
            return jax.tree.map(
                lambda x: jnp.repeat(x[None], d_total, axis=0), template)

    def per_shard(tables):
        with enable_x64():
            local = jax.tree.map(lambda x: x[0], tables)
            assign, valid, total, ov, hits, local = eval_fn(
                part.shard_frontier(), local)
            total = jax.lax.psum(total, part.all_axes)
            ov = jax.lax.psum(ov.astype(jnp.int32), part.all_axes)
            hits = jax.lax.psum(hits, part.all_axes)
            return (assign[None], valid[None], total, ov, hits,
                    jax.tree.map(lambda x: x[None], local))

    fn = _X64Jit(shard_map(
        per_shard, mesh=mesh, in_specs=(table_specs,),
        out_specs=(spec, spec, P(), P(), P(), table_specs),
        check_rep=False))

    def run(tables: Optional[Dict[int, tuple]] = None):
        if tables is None:
            tables = init_tables()
        with mesh:
            assign, valid, total, ov, hits, tables = fn(tables)
        a, v, t, o, h = device_get((assign, valid, total, ov, hits),
                                   "dist-eval-rows")
        a, v = np.asarray(a), np.asarray(v)
        rows = np.concatenate([a[i][v[i]] for i in range(a.shape[0])],
                              axis=0) if a.shape[0] else \
            np.zeros((0, len(eng.order)), np.int32)
        # "overflow" is a bool on every evaluation surface
        # (evaluate_static included); the shard count rides separately
        stats = {"count": int(t), "overflow": bool(o),
                 "overflow_shards": int(o), "tier2_replay_hits": int(h)}
        return rows, stats, tables

    return run, eng


class _X64Jit:
    """jit wrapper that traces/lowers under enable_x64.

    The shard body builds int64 counts/keys, so the x64 scope must cover
    tracing *and* lowering; entering it only inside the traced function
    leaves lowering (triggered by the first call or ``.lower()`` outside
    any scope) with mixed 32/64-bit IR that fails stablehlo verification.
    """

    def __init__(self, fn):
        self._jit = jax.jit(fn)

    def __call__(self, *args, **kwargs):
        with enable_x64():
            return self._jit(*args, **kwargs)

    def lower(self, *args, **kwargs):
        with enable_x64():
            return self._jit.lower(*args, **kwargs)

"""Distributed CLFTJ: a fully-jittable static pipeline + mesh execution.

The host-driven engine (``cached_frontier``) splits morsels adaptively; for
SPMD execution we instead fix the chunk capacity, interpret the lowered op
schedule at trace time (``schedule.execute_static``), and flag overflow
instead of splitting.  The result is one pure function
(frontier₀, cache tables) → (count, overflow, tables) that
``shard_map``s across the mesh: each shard owns a contiguous slice of the
top-level variable's candidate runs (the natural LFTJ work partition — see
DESIGN.md §3), keeps a private cache (caching is an optimization, never a
correctness requirement, so no coherence traffic), and the only collective
is the final count psum.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .cache import CacheConfig
from .cached_frontier import JaxCachedTrieJoin, _resolve_cache_config
from .cq import CQ
from .db import Database
from .frontier import Frontier
from .schedule import execute_static
from .td import TreeDecomposition


class StaticCLFTJ(JaxCachedTrieJoin):
    """Jittable fixed-capacity CLFTJ (no host-side morsel splitting).

    Tier-2 tables are (S, W) arrays per the configured :class:`CacheConfig`
    policy; each shard keeps a private table (no coherence traffic) and the
    LRU tick is a static counter baked in by the unrolled op schedule —
    the *same* lowered schedule the host executor interprets, run through
    ``schedule.execute_static`` instead of a third recursion copy."""

    # -----------------------------------------------------------------
    def count_fn(self):
        """Returns a pure fn(frontier0) -> (count, overflow)."""
        cfg = self.cache_config
        n_sets = max(1, cfg.initial_slots() // cfg.ways)

        def fn(F0: Frontier):
            tables = {c: (jnp.zeros((n_sets, cfg.ways), jnp.int64),
                          jnp.zeros((n_sets, cfg.ways), jnp.int64),
                          jnp.zeros((n_sets, cfg.ways), bool),
                          jnp.zeros((n_sets, cfg.ways), jnp.int32),
                          jnp.zeros((n_sets, cfg.ways), jnp.int64))
                      for c in range(self.td.num_nodes)
                      if cfg.initial_slots() > 0 and self._node_cacheable(c)}
            total, ov, _ = execute_static(self.schedule, self, F0, tables,
                                          cfg)
            return total, ov

        return fn


def make_distributed_count(q: CQ, td: TreeDecomposition,
                           order: Sequence[str], db: Database, mesh: Mesh,
                           capacity: int = 1 << 14,
                           axes: Tuple[str, ...] = ("data",),
                           cache: Optional[CacheConfig] = None,
                           expand_kernel: str = "auto"):
    """Build (jitted_fn, engine).  ``jitted_fn()`` -> (count, overflow).

    Work partition: shard i of D takes top-level guard runs
    [i·R/D, (i+1)·R/D); relations are replicated (closure constants); the
    final count is a psum over the mesh axes — the single collective.
    ``expand_kernel`` is resolved per spec at trace time (the registry
    choice is baked into the unrolled schedule, identically per shard).
    """
    cache = _resolve_cache_config(cache, None, default_slots=1 << 15)
    eng = StaticCLFTJ(q, td, order, db, capacity=capacity, cache=cache,
                      expand_kernel=expand_kernel)
    g_ai, g_lvl = eng.at_depth[0][eng.guard[0]]
    rs = eng.levels[g_ai][g_lvl].runstarts
    nruns = rs.shape[0]
    n_rows_g = eng.sizes[g_ai]
    count_fn = eng.count_fn()
    all_axes = tuple(a for a in axes if a in mesh.axis_names)
    d_total = int(np.prod([mesh.shape[a] for a in all_axes]))

    def per_shard():
        with enable_x64():
            idx = jnp.zeros((), jnp.int32)
            mult = 1
            for a in reversed(all_axes):
                idx = idx + jax.lax.axis_index(a) * mult
                mult *= mesh.shape[a]
            r0 = (idx * nruns) // d_total
            r1 = ((idx + 1) * nruns) // d_total
            lo0 = jnp.where(r0 < nruns, rs[jnp.clip(r0, 0, nruns - 1)],
                            n_rows_g).astype(jnp.int32)
            hi0 = jnp.where(r1 < nruns, rs[jnp.clip(r1, 0, nruns - 1)],
                            n_rows_g).astype(jnp.int32)
            F0 = eng.initial_frontier()
            F0 = F0._replace(
                lo=F0.lo.at[0, g_ai].set(lo0),
                hi=F0.hi.at[0, g_ai].set(hi0))
            total, ov = count_fn(F0)
            total = jax.lax.psum(total, all_axes)
            ov = jax.lax.psum(ov.astype(jnp.int32), all_axes)
            return total, ov

    fn = shard_map(per_shard, mesh=mesh, in_specs=(),
                   out_specs=(P(), P()), check_rep=False)
    return _X64Jit(fn), eng


class _X64Jit:
    """jit wrapper that traces/lowers under enable_x64.

    The shard body builds int64 counts/keys, so the x64 scope must cover
    tracing *and* lowering; entering it only inside the traced function
    leaves lowering (triggered by the first call or ``.lower()`` outside
    any scope) with mixed 32/64-bit IR that fails stablehlo verification.
    """

    def __init__(self, fn):
        self._jit = jax.jit(fn)

    def __call__(self, *args, **kwargs):
        with enable_x64():
            return self._jit(*args, **kwargs)

    def lower(self, *args, **kwargs):
        with enable_x64():
            return self._jit.lower(*args, **kwargs)

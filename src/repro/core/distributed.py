"""Distributed CLFTJ: a fully-jittable static pipeline + mesh execution.

The host-driven engine (``cached_frontier``) splits morsels adaptively; for
SPMD execution we instead fix the chunk capacity, unroll the TD recursion
(it is static), and flag overflow instead of splitting.  The result is one
pure function (frontier₀, cache tables) → (count, overflow, tables) that
``shard_map``s across the mesh: each shard owns a contiguous slice of the
top-level variable's candidate runs (the natural LFTJ work partition — see
DESIGN.md §3), keeps a private cache (caching is an optimization, never a
correctness requirement, so no coherence traffic), and the only collective
is the final count psum.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .cache import CacheConfig, _insert as _cache_insert, \
    _probe as _cache_probe
from .cached_frontier import (JaxCachedTrieJoin, _apply_counts, _dedup,
                              _make_rep_frontier, _pack_keys, _segment_counts)
from .cq import CQ
from .db import Database
from .frontier import Frontier
from .td import TreeDecomposition


class StaticCLFTJ(JaxCachedTrieJoin):
    """Jittable fixed-capacity CLFTJ (no host-side morsel splitting).

    Tier-2 tables are (S, W) arrays per the configured :class:`CacheConfig`
    policy; each shard keeps a private table (no coherence traffic) and the
    LRU tick is a static counter baked in by the unrolled TD recursion."""

    # -----------------------------------------------------------------
    def count_fn(self):
        """Returns a pure fn(frontier0) -> (count, overflow)."""
        cfg = self.cache_config
        n_sets = max(1, cfg.initial_slots() // cfg.ways)

        def fn(F0: Frontier):
            tables = {c: (jnp.zeros((n_sets, cfg.ways), jnp.int64),
                          jnp.zeros((n_sets, cfg.ways), jnp.int64),
                          jnp.zeros((n_sets, cfg.ways), bool),
                          jnp.zeros((n_sets, cfg.ways), jnp.int32),
                          jnp.zeros((n_sets, cfg.ways), jnp.int64))
                      for c in range(self.td.num_nodes)
                      if cfg.initial_slots() > 0 and self._node_cacheable(c)}
            self._tick = 0
            exits, ov, tables = self._static_node(self.td.root, F0,
                                                  jnp.zeros((), bool), tables)
            total = jnp.sum(jnp.where(exits.valid, exits.factor, 0))
            return total, ov

        return fn

    def _static_node(self, v: int, F: Frontier, ov, tables):
        for d in self._owned_depths(v):
            F, needed = self._expand_fn(d)(F)
            ov = ov | (needed > self.capacity)
        for c in self.td.children[v]:
            F, ov, tables = self._static_child(c, F, ov, tables)
        return F, ov, tables

    def _static_child(self, c: int, F: Frontier, ov, tables):
        C = self.capacity
        adh = self.plan.adhesion_idx[c]
        cacheable = self._node_cacheable(c)
        use_t2 = cacheable and c in tables
        use_t1 = self.dedup and cacheable

        keys = _pack_keys(F.assign, adh, c) if cacheable else None
        if use_t2:
            tk, tv, tu, ts, tc = tables[c]
            self._tick += 1
            hit, hvals, ts = _cache_probe(tk, tv, tu, ts, keys, F.valid,
                                          jnp.int32(self._tick))
            tables = dict(tables)
            tables[c] = (tk, tv, tu, ts, tc)
        else:
            hit = jnp.zeros((C,), bool)
            hvals = jnp.zeros((C,), jnp.int64)
        active = F.valid & ~hit
        if use_t1:
            first_idx, rep_of_row, n_reps = _dedup(keys, active)
            R = _make_rep_frontier(F, first_idx, n_reps)
        else:
            rep_of_row = jnp.arange(C, dtype=jnp.int32)
            R = F._replace(factor=jnp.where(active, 1, 0).astype(jnp.int64),
                           valid=active,
                           orig=jnp.arange(C, dtype=jnp.int32))
        exits, ov, tables = self._static_node(c, R, ov, tables)
        cnt = _segment_counts(exits, C)
        if use_t2:
            rep_keys = keys[jnp.clip(first_idx, 0, C - 1)] if use_t1 else keys
            rep_active = (jnp.arange(C) < n_reps) if use_t1 else active
            self._tick += 1
            out = _cache_insert(*tables[c], rep_keys, cnt,
                                jnp.maximum(cnt, 1), rep_active,
                                jnp.int32(self._tick),
                                policy=self.cache_config.policy,
                                rounds=min(self.cache_config.ways, 8))
            tables = dict(tables)
            tables[c] = out[:5]
        return _apply_counts(F, hit, hvals, rep_of_row, cnt), ov, tables


def make_distributed_count(q: CQ, td: TreeDecomposition,
                           order: Sequence[str], db: Database, mesh: Mesh,
                           capacity: int = 1 << 14,
                           cache_slots: int = 1 << 15,
                           axes: Tuple[str, ...] = ("data",),
                           cache: Optional[CacheConfig] = None):
    """Build (jitted_fn, engine).  ``jitted_fn()`` -> (count, overflow).

    Work partition: shard i of D takes top-level guard runs
    [i·R/D, (i+1)·R/D); relations are replicated (closure constants); the
    final count is a psum over the mesh axes — the single collective.
    """
    eng = StaticCLFTJ(q, td, order, db, capacity=capacity,
                      cache_slots=cache_slots, cache=cache)
    g_ai, g_lvl = eng.at_depth[0][eng.guard[0]]
    rs = eng.levels[g_ai][g_lvl].runstarts
    nruns = rs.shape[0]
    n_rows_g = eng.sizes[g_ai]
    count_fn = eng.count_fn()
    all_axes = tuple(a for a in axes if a in mesh.axis_names)
    d_total = int(np.prod([mesh.shape[a] for a in all_axes]))

    def per_shard():
        with enable_x64():
            idx = jnp.zeros((), jnp.int32)
            mult = 1
            for a in reversed(all_axes):
                idx = idx + jax.lax.axis_index(a) * mult
                mult *= mesh.shape[a]
            r0 = (idx * nruns) // d_total
            r1 = ((idx + 1) * nruns) // d_total
            lo0 = jnp.where(r0 < nruns, rs[jnp.clip(r0, 0, nruns - 1)],
                            n_rows_g).astype(jnp.int32)
            hi0 = jnp.where(r1 < nruns, rs[jnp.clip(r1, 0, nruns - 1)],
                            n_rows_g).astype(jnp.int32)
            F0 = eng.initial_frontier()
            F0 = F0._replace(
                lo=F0.lo.at[0, g_ai].set(lo0),
                hi=F0.hi.at[0, g_ai].set(hi0))
            total, ov = count_fn(F0)
            total = jax.lax.psum(total, all_axes)
            ov = jax.lax.psum(ov.astype(jnp.int32), all_axes)
            return total, ov

    fn = shard_map(per_shard, mesh=mesh, in_specs=(),
                   out_specs=(P(), P()), check_rep=False)
    return _X64Jit(fn), eng


class _X64Jit:
    """jit wrapper that traces/lowers under enable_x64.

    The shard body builds int64 counts/keys, so the x64 scope must cover
    tracing *and* lowering; entering it only inside the traced function
    leaves lowering (triggered by the first call or ``.lower()`` outside
    any scope) with mixed 32/64-bit IR that fails stablehlo verification.
    """

    def __init__(self, fn):
        self._jit = jax.jit(fn)

    def __call__(self, *args, **kwargs):
        with enable_x64():
            return self._jit(*args, **kwargs)

    def lower(self, *args, **kwargs):
        with enable_x64():
            return self._jit.lower(*args, **kwargs)

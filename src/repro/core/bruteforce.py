"""Tiny pairwise-join oracle for tests (Selinger-style, dict-merged)."""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from .cq import CQ
from .db import Database


def brute_force_evaluate(q: CQ, db: Database) -> Set[Tuple[int, ...]]:
    """All satisfying assignments, as tuples over ``q.variables``."""
    assignments: List[Dict[str, int]] = [dict()]
    for atom in q.atoms:
        rel = db.relations[atom.relation]
        nxt: List[Dict[str, int]] = []
        for mu in assignments:
            for row in rel:
                ok = True
                ext = dict(mu)
                for x, val in zip(atom.vars, row):
                    val = int(val)
                    if x in ext:
                        if ext[x] != val:
                            ok = False
                            break
                    else:
                        ext[x] = val
                if ok:
                    nxt.append(ext)
        assignments = nxt
        if not assignments:
            return set()
    allv = q.variables
    return {tuple(mu[x] for x in allv) for mu in assignments}


def brute_force_count(q: CQ, db: Database) -> int:
    return len(brute_force_evaluate(q, db))

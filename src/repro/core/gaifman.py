"""Gaifman graph and basic undirected-graph utilities (paper §2.1, §2.2).

Plain Python adjacency sets — query graphs have a handful of nodes; planning
runs on the host, never on the accelerator.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, List, Set, Tuple

from .cq import CQ

Graph = Dict[str, Set[str]]


def gaifman_graph(q: CQ) -> Graph:
    """Undirected graph on vars(q); edge iff co-occurrence in a subgoal."""
    g: Graph = {v: set() for v in q.variables}
    for atom in q.atoms:
        vs = atom.vars
        for i in range(len(vs)):
            for j in range(i + 1, len(vs)):
                if vs[i] != vs[j]:
                    g[vs[i]].add(vs[j])
                    g[vs[j]].add(vs[i])
    return g


def induced_subgraph(g: Graph, nodes: Iterable[str]) -> Graph:
    """g[U] — the subgraph induced by ``nodes`` (paper notation g[U])."""
    ns = set(nodes)
    return {v: (g[v] & ns) for v in g if v in ns}


def remove_nodes(g: Graph, removed: Iterable[str]) -> Graph:
    """g - S."""
    rs = set(removed)
    return induced_subgraph(g, set(g) - rs)


def connected_components(g: Graph) -> List[Set[str]]:
    """Connected components, deterministic order (sorted roots)."""
    seen: Set[str] = set()
    comps: List[Set[str]] = []
    for root in sorted(g):
        if root in seen:
            continue
        comp = {root}
        stack = [root]
        while stack:
            u = stack.pop()
            for w in g[u]:
                if w not in comp:
                    comp.add(w)
                    stack.append(w)
        seen |= comp
        comps.append(comp)
    return comps


def is_connected(g: Graph) -> bool:
    return len(connected_components(g)) <= 1 if g else True


def is_separating_set(g: Graph, s: Set[str]) -> bool:
    """S separates g iff g - S is disconnected (paper §2.1).

    Note the paper's definition requires g - S to be *disconnected*, which in
    particular requires it to have >= 2 nodes.
    """
    rest = remove_nodes(g, s)
    return len(connected_components(rest)) >= 2


def neighbors_of_set(g: Graph, s: Set[str]) -> Set[str]:
    out: Set[str] = set()
    for v in s:
        out |= g[v]
    return out - s

"""Pluggable tier-2 device cache for the vectorized CLFTJ (DESIGN.md §2.3).

The paper's central knob is *flexibility*: "our solution balances memory
usage and repeated computation" by choosing how much cache to keep and what
to admit/evict (§3.4, Fig 10).  The frontier engine realizes the cache as
device arrays updated with functional scatter/gather, so a "policy" here is
a pair of jitted ops (probe, insert) over a fixed table layout:

* ``direct``    — 1-way direct-mapped table: ``slot = hash(key) % S``;
  collisions overwrite unconditionally (hardware-style, zero metadata).
* ``setassoc``  — N-way set-associative with LRU within each set: a key may
  live in any of ``assoc`` ways of its set; the victim is the invalid way
  if one exists, else the least-recently-touched way.  Conflict misses on
  skewed key distributions drop sharply vs ``direct`` at equal slot count.
* ``costaware`` — set-associative layout, but the victim is the *cheapest*
  resident entry and admission is refused when the incumbent is more
  valuable than the candidate.  Cost is the cached subtree count — a proxy
  for the recomputation a future hit would avoid (big subtrees are the
  entries worth pinning).

All policies are *caches of exact results*: correctness never depends on
what is resident, only speed does (the paper's optionality property), so
batched scatter collisions may drop arbitrary writers without harm.

``CacheManager`` owns one ``DeviceCache`` per TD node and the **dynamic
sizing controller** (the Fig 10 size knob made adaptive): between subtree
launches it grows a table whose misses look like conflict pressure (low
hit rate at high occupancy) while total slots stay within ``budget``, and
shrinks tables whose occupancy stays low (memory handed back).  Resizing
rehashes resident entries into the new table with one batched insert;
entries lost to rehash collisions are a performance non-event by the
optionality property above.

**Row-block payloads (DESIGN.md §2.6).**  With ``cache_payloads=True`` a
table additionally stores, per way, an ``(offset, length)`` pointer into a
per-node *slab arena* of factorized row blocks: the subtree-column
assignments of one adhesion key's complete subtree result (paper §3.4's
factorized intermediates).  Evaluation-mode hits replay the block instead
of re-expanding the bag.  The slab is a bump-pointer arena — blocks whose
keys are evicted become dead space until the arena wraps, at which point
every payload is invalidated in one epoch *flush* (keys and counts stay
resident for count mode).  A payload-bearing hit requires ``pay_len >= 0``;
the metadata planes ride :func:`_insert`'s election (the ``pay`` pytree)
on every insert, with count-mode inserts writing the ``-1`` sentinel, so
an evicting write can never leave a stale block reachable under a new key.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from .hostsync import device_get

_MIX = np.int64(-7046029254386353131)  # 0x9E3779B97F4A7C15 as signed

POLICIES = ("direct", "setassoc", "costaware")


def _hash_sets(keys: jnp.ndarray, n_sets: int) -> jnp.ndarray:
    h = keys * _MIX
    h = h ^ (h >> 29)
    return jnp.abs(h) % n_sets


@dataclass(frozen=True)
class CacheConfig:
    """Tier-2 cache knobs (engine-facing; see DESIGN.md §2.3).

    * ``policy``: "direct" | "setassoc" | "costaware".
    * ``slots``: initial entries per node table (0 disables tier 2).
    * ``assoc``: ways per set (ignored for "direct", which is 1-way).
    * ``dynamic``: enable the sizing controller.
    * ``budget``: max total slots summed over all node tables (None = only
      bounded by ``max_slots`` per table); also the dynamic controller's
      growth headroom.  Floor: every cached node keeps at least one set,
      so with budget < nodes × ways the total can exceed it by that floor.
    * ``min_slots``/``max_slots``: per-table resize clamps.
    * ``resize_interval``: subtree launches between controller decisions.
    * ``grow_below_hit_rate``: grow when window hit-rate is below this and
      the table looks conflict-bound (occupancy > 1/2).
    * ``shrink_below_occupancy``: shrink when occupancy stays under this.
    * ``enabled_nodes``: restrict caching to these TD nodes (None = all).
    * ``cache_payloads``: additionally store factorized row *blocks* per
      entry (evaluation-mode replay-on-hit, DESIGN.md §2.6).
    * ``payload_rows``: per-node slab arena size in rows (the memory half
      of the paper's size↔recomputation trade-off for evaluation).
    * ``payload_throttle_probes`` / ``payload_throttle_hit_rate``: the
      admission throttle (§3.4's admission flexibility applied to
      blocks): after that many evaluation probes a table whose payload
      hit rate is still below the floor stops *storing* new blocks —
      workloads whose adhesion keys never recur shouldn't pay the
      arena-write overhead.  Splicing of already-stored blocks, and
      storing again if the rate recovers, are unaffected.
    * ``payload_probation``: while throttled, still store on every Nth
      throttled fold (0 disables) — with nothing resident the hit rate
      could never recover on a workload shift.
    """

    policy: str = "direct"
    slots: int = 1 << 16
    assoc: int = 4
    dynamic: bool = False
    budget: Optional[int] = None
    min_slots: int = 1 << 8
    max_slots: int = 1 << 22
    resize_interval: int = 8
    grow_below_hit_rate: float = 0.5
    shrink_below_occupancy: float = 0.125
    enabled_nodes: Optional[frozenset] = None
    cache_payloads: bool = False
    payload_rows: int = 1 << 15
    payload_throttle_probes: int = 1 << 15
    payload_throttle_hit_rate: float = 0.01
    payload_probation: int = 16

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown cache policy {self.policy!r}; "
                             f"expected one of {POLICIES}")
        if self.assoc < 1:
            raise ValueError("assoc must be >= 1")
        if self.cache_payloads and self.payload_rows < 1:
            raise ValueError("cache_payloads needs payload_rows >= 1")

    @property
    def ways(self) -> int:
        return 1 if self.policy == "direct" else int(self.assoc)

    def initial_slots(self) -> int:
        s = int(self.slots)
        if self.budget is not None:
            s = min(s, int(self.budget))
        if s <= 0:
            return 0
        # whole sets only; a positive request below one set rounds UP to a
        # single set rather than silently disabling the cache
        w = self.ways
        return max(w, (s // w) * w)


# ---------------------------------------------------------------------------
# Jitted table ops.  Tables are (S, W) arrays: S sets, W ways.
# ---------------------------------------------------------------------------

@jax.jit
def _probe(tkeys, tvals, tused, tstamp, keys, active, tick):
    """Batched lookup; returns (hit, vals, stamp') — stamp' records the LRU
    touch of every hit way (scatter-max, so duplicate rows are harmless)."""
    n_sets = tkeys.shape[0]
    sets = _hash_sets(keys, n_sets)
    match = tused[sets] & (tkeys[sets] == keys[:, None]) & active[:, None]
    hit = match.any(axis=1)
    way = jnp.argmax(match, axis=1)
    vals = jnp.where(hit, tvals[sets, way], 0)
    stamp = tstamp.at[sets, way].max(jnp.where(hit, tick, -1))
    return hit, vals, stamp


@functools.partial(jax.jit, static_argnames=("policy", "rounds"))
def _insert(tkeys, tvals, tused, tstamp, tcost,
            keys, vals, costs, active, tick, *, policy: str,
            rounds: int = 1, pay=None):
    """Batched fill.  Victim selection per policy.

    Each round elects exactly one writer per set (scatter-max of the row
    index — duplicate-index scatters must not carry the write mask, or a
    masked row's "keep old value" no-op can land after a real admit and
    clobber it) and writes through per-set *unique* indices.  ``rounds``
    (≈ the way count) re-reads the updated table so batch collisions retry
    into the remaining ways instead of being dropped — without it an N-way
    table admits N× fewer entries per launch than a direct-mapped one of
    equal size.

    ``pay`` (``None`` or ``(tpoff, tplen, poff, plen)``, resolved at trace
    time) carries the payload metadata planes through the same election.
    Two payload-specific rules:

    * every admitted write also writes ``(poff, plen)`` — count-mode
      inserts pass the ``plen = -1`` sentinel, so an eviction can never
      leave the victim's block reachable under the new key;
    * a resident key only blocks re-admission when it already carries a
      payload (or the candidate has none): a payload-bearing candidate
      refreshes its resident way in place, so evaluation mode can attach
      blocks to keys first seen by ``count()``.
    """
    n_sets = tkeys.shape[0]
    C = keys.shape[0]
    rows = jnp.arange(C, dtype=jnp.int32)
    sets = jnp.where(active, _hash_sets(keys, n_sets), 0)
    remaining = active
    if pay is not None:
        tpoff, tplen, poff, plen = pay
        cand_pay = plen >= 0
    n_admit = jnp.int32(0)
    n_evict = jnp.int32(0)
    for _ in range(max(1, rounds)):
        way_used = tused[sets]                       # (C, W)
        resident = way_used & (tkeys[sets] == keys[:, None])
        if pay is not None:
            blocking = resident & ((tplen[sets] >= 0) | ~cand_pay[:, None])
        else:
            blocking = resident                      # dup already admitted
        rem = remaining & ~blocking.any(axis=1)
        any_free = ~way_used.all(axis=1)
        free_way = jnp.argmin(way_used, axis=1)      # first invalid way
        if policy == "costaware":
            contested = jnp.argmin(jnp.where(way_used, tcost[sets],
                                             jnp.int64(2 ** 62)), axis=1)
        else:  # direct (W=1 → way 0) and setassoc both take the LRU way
            contested = jnp.argmin(jnp.where(way_used, tstamp[sets],
                                             jnp.int32(2 ** 31 - 1)), axis=1)
        victim = jnp.where(any_free, free_way, contested)
        has_res = jnp.zeros((C,), bool)
        if pay is not None:
            # a payload-less resident is refreshed in its own way
            has_res = resident.any(axis=1)
            victim = jnp.where(has_res, jnp.argmax(resident, axis=1),
                               victim)
        admit = rem
        if policy == "costaware":
            incumbent = tcost[sets, victim]
            admit = admit & (has_res | any_free | (costs >= incumbent))
        # elect one admitted writer per set (highest row index)
        winner = jnp.full((n_sets,), -1, jnp.int32).at[sets].max(
            jnp.where(admit, rows, -1))
        src = jnp.clip(winner, 0, C - 1)             # (S,) winning row
        do_w = winner >= 0
        sel = (jnp.arange(n_sets), victim[src])      # unique per set
        tkeys = tkeys.at[sel].set(jnp.where(do_w, keys[src], tkeys[sel]))
        tvals = tvals.at[sel].set(jnp.where(do_w, vals[src], tvals[sel]))
        tcost = tcost.at[sel].set(jnp.where(do_w, costs[src], tcost[sel]))
        tstamp = tstamp.at[sel].set(jnp.where(do_w, tick, tstamp[sel]))
        if pay is not None:
            tpoff = tpoff.at[sel].set(jnp.where(do_w, poff[src],
                                                tpoff[sel]))
            tplen = tplen.at[sel].set(jnp.where(do_w, plen[src],
                                                tplen[sel]))
        tused = tused.at[sel].set(tused[sel] | do_w)
        won = admit & (winner[sets] == rows)
        n_admit = n_admit + jnp.sum(won.astype(jnp.int32))
        n_evict = n_evict + jnp.sum(
            (won & ~any_free & ~has_res).astype(jnp.int32))
        remaining = rem & ~won
    if pay is not None:
        return (tkeys, tvals, tused, tstamp, tcost, tpoff, tplen,
                n_admit, n_evict)
    return tkeys, tvals, tused, tstamp, tcost, n_admit, n_evict


@jax.jit
def _probe_payload(tkeys, tused, tstamp, tpoff, tplen, keys, active, tick):
    """Evaluation-mode lookup: a hit additionally requires a resident row
    block (``pay_len >= 0``) — entries inserted count-only are misses here.
    Returns (hit, poff, plen, stamp')."""
    n_sets = tkeys.shape[0]
    sets = _hash_sets(keys, n_sets)
    match = (tused[sets] & (tkeys[sets] == keys[:, None])
             & (tplen[sets] >= 0) & active[:, None])
    hit = match.any(axis=1)
    way = jnp.argmax(match, axis=1)
    poff = jnp.where(hit, tpoff[sets, way], 0)
    plen = jnp.where(hit, tplen[sets, way], 0)
    stamp = tstamp.at[sets, way].max(jnp.where(hit, tick, -1))
    return hit, poff, plen, stamp


# ---------------------------------------------------------------------------

@dataclass
class DeviceCache:
    """One node's table: functional arrays + deferred stats/controller.

    Stats are accumulated *on device* (the ``_acc_*`` fields hold lazy
    scalars) so probing/inserting never forces a host sync on the hot
    path; ``hits``/``misses``/... properties and :meth:`stats` fetch them
    once, through the :mod:`hostsync` funnel, when actually read."""

    config: CacheConfig
    keys: jnp.ndarray    # (S, W) int64
    vals: jnp.ndarray    # (S, W) int64
    used: jnp.ndarray    # (S, W) bool
    stamp: jnp.ndarray   # (S, W) int32  — LRU clock (ticks)
    cost: jnp.ndarray    # (S, W) int64  — recomputation-cost proxy
    # payload region (None unless config.cache_payloads) — DESIGN.md §2.6
    pay_off: Optional[jnp.ndarray] = None  # (S, W) int32 — slab offset
    pay_len: Optional[jnp.ndarray] = None  # (S, W) int32 — block rows; -1=none
    slab: Optional[jnp.ndarray] = None     # (payload_rows+1, width) int32;
    #                                        last row = masked-write scratch
    slab_bump: int = 0                     # host-side arena bump pointer
    payload_flushes: int = 0
    payload_skips: int = 0                 # eligible blocks not stored
    payload_throttled: int = 0             # folds skipped by the throttle
    # host-visible evaluation-probe counters feeding the store throttle
    # (maintained by the executor from its per-fold planning fetch — no
    # extra device sync)
    eval_probes_h: int = 0
    eval_hits_h: int = 0
    tick: int = 0
    resizes: int = 0
    window_launches: int = 0
    # device-side accumulators (int until the first op touches them)
    _acc_hits: object = 0
    _acc_misses: object = 0
    _acc_probes: object = 0
    _acc_inserts: object = 0
    _acc_evictions: object = 0
    _acc_payload_hits: object = 0
    # sliding window consumed by the sizing controller
    _acc_window_hits: object = 0
    _acc_window_probes: object = 0

    @property
    def hits(self) -> int:
        return int(device_get(self._acc_hits, "cache-stat"))

    @property
    def misses(self) -> int:
        return int(device_get(self._acc_misses, "cache-stat"))

    @property
    def probes(self) -> int:
        return int(device_get(self._acc_probes, "cache-stat"))

    @property
    def inserts(self) -> int:
        return int(device_get(self._acc_inserts, "cache-stat"))

    @property
    def evictions(self) -> int:
        return int(device_get(self._acc_evictions, "cache-stat"))

    @property
    def payload_hits(self) -> int:
        return int(device_get(self._acc_payload_hits, "cache-stat"))

    @staticmethod
    def create(config: CacheConfig,
               slots: Optional[int] = None) -> "DeviceCache":
        n = config.initial_slots() if slots is None else int(slots)
        w = config.ways
        s = max(1, n // w)
        pay_off = pay_len = None
        if config.cache_payloads:
            pay_off = jnp.zeros((s, w), jnp.int32)
            pay_len = jnp.full((s, w), -1, jnp.int32)
        return DeviceCache(
            config=config,
            keys=jnp.zeros((s, w), jnp.int64),
            vals=jnp.zeros((s, w), jnp.int64),
            used=jnp.zeros((s, w), bool),
            stamp=jnp.zeros((s, w), jnp.int32),
            cost=jnp.zeros((s, w), jnp.int64),
            pay_off=pay_off, pay_len=pay_len)

    # -- capacity ------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return int(self.keys.shape[0] * self.keys.shape[1])

    def occupancy(self) -> int:
        return int(device_get(jnp.sum(self.used), "cache-occupancy"))

    # -- ops -----------------------------------------------------------
    def probe(self, qkeys: jnp.ndarray,
              active: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        self.tick += 1
        hit, vals, stamp = _probe(self.keys, self.vals, self.used,
                                  self.stamp, qkeys, active,
                                  jnp.int32(self.tick))
        self.stamp = stamp
        # device-side accounting: no host sync on the probe path
        n_active = jnp.sum(active.astype(jnp.int64))
        n_hit = jnp.sum(hit.astype(jnp.int64))
        self._acc_probes = self._acc_probes + n_active
        self._acc_hits = self._acc_hits + n_hit
        self._acc_misses = self._acc_misses + (n_active - n_hit)
        self._acc_window_probes = self._acc_window_probes + n_active
        self._acc_window_hits = self._acc_window_hits + n_hit
        return hit, vals

    def probe_payload(self, qkeys: jnp.ndarray, active: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Evaluation-mode lookup: hit only on entries with a resident row
        block; returns (hit, slab offset, block length)."""
        assert self.pay_off is not None, "cache_payloads is off"
        self.tick += 1
        hit, poff, plen, stamp = _probe_payload(
            self.keys, self.used, self.stamp, self.pay_off, self.pay_len,
            qkeys, active, jnp.int32(self.tick))
        self.stamp = stamp
        n_active = jnp.sum(active.astype(jnp.int64))
        n_hit = jnp.sum(hit.astype(jnp.int64))
        self._acc_probes = self._acc_probes + n_active
        self._acc_hits = self._acc_hits + n_hit
        self._acc_misses = self._acc_misses + (n_active - n_hit)
        self._acc_payload_hits = self._acc_payload_hits + n_hit
        self._acc_window_probes = self._acc_window_probes + n_active
        self._acc_window_hits = self._acc_window_hits + n_hit
        return hit, poff, plen

    def insert(self, qkeys: jnp.ndarray, vals: jnp.ndarray,
               active: jnp.ndarray,
               costs: Optional[jnp.ndarray] = None,
               poff: Optional[jnp.ndarray] = None,
               plen: Optional[jnp.ndarray] = None) -> None:
        self.tick += 1
        if costs is None:  # default proxy: the count itself (clipped >= 1)
            costs = jnp.maximum(vals, 1)
        if self.pay_off is not None:
            # payload tables carry the metadata planes through EVERY
            # insert so evicting writes always overwrite them (count
            # inserts carry the -1 sentinel — never a stale block)
            C = qkeys.shape[0]
            if poff is None:
                poff = jnp.zeros((C,), jnp.int32)
                plen = jnp.full((C,), -1, jnp.int32)
            out = _insert(
                self.keys, self.vals, self.used, self.stamp, self.cost,
                qkeys, vals, costs.astype(jnp.int64), active,
                jnp.int32(self.tick), policy=self.config.policy,
                rounds=min(self.config.ways, 8),
                pay=(self.pay_off, self.pay_len, poff, plen))
            (self.keys, self.vals, self.used, self.stamp, self.cost,
             self.pay_off, self.pay_len, n_ins, n_evict) = out
        else:
            out = _insert(self.keys, self.vals, self.used, self.stamp,
                          self.cost, qkeys, vals, costs.astype(jnp.int64),
                          active, jnp.int32(self.tick),
                          policy=self.config.policy,
                          rounds=min(self.config.ways, 8))
            (self.keys, self.vals, self.used, self.stamp, self.cost,
             n_ins, n_evict) = out
        self._acc_inserts = self._acc_inserts + n_ins
        self._acc_evictions = self._acc_evictions + n_evict
        self.window_launches += 1

    # -- payload slab arena (DESIGN.md §2.6) ---------------------------
    def ensure_slab(self, width: int) -> None:
        """Lazily allocate the block arena: ``payload_rows`` rows of the
        node's subtree width, plus one scratch row for masked writes."""
        if self.slab is None:
            self.slab = jnp.zeros((int(self.config.payload_rows) + 1, width),
                                  jnp.int32)
        elif self.slab.shape[1] != width:
            raise ValueError(
                f"slab width {self.slab.shape[1]} != subtree width {width}")

    def alloc_blocks(self, lens: np.ndarray, active: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side bump allocation of one batch of variable-length blocks.

        ``lens[i]`` rows are requested for candidate row ``i`` (``active``
        masks real candidates).  Blocks larger than the whole arena are
        refused outright (they could never fit, and must not trigger a
        pointless flush or veto later candidates).  If the rest of the
        batch does not fit the remaining arena, the arena is *flushed*
        first (every payload invalidated — keys/counts stay resident;
        after a flush at least the first candidate is guaranteed to
        admit); candidates still beyond capacity are refused prefix-wise.
        Returns ``(offsets, admitted)`` (numpy, host) — refusals only
        cost future recomputation.
        """
        cap = int(self.config.payload_rows)
        lens = np.where(active, np.asarray(lens, np.int64), 0)
        lens = np.where(lens <= cap, lens, 0)  # can never fit: refuse
        total = int(lens.sum())
        if total > cap - self.slab_bump and self.slab_bump > 0 and total:
            self.flush_payloads()
        cum = np.cumsum(lens)
        admit = (lens > 0) & (cum <= cap - self.slab_bump)
        offs = np.where(admit, self.slab_bump + cum - lens, 0).astype(
            np.int32)
        if admit.any():
            self.slab_bump += int(lens[admit].sum())
        return offs, admit

    def note_eval_probes(self, probes: int, hits: int) -> None:
        """Feed the store throttle (host counters, no device sync).  The
        counters decay exponentially past 4× the probe floor — a sliding
        window, so a miss-heavy prefix cannot latch the throttle against
        a workload that later starts recurring."""
        self.eval_probes_h += int(probes)
        self.eval_hits_h += int(hits)
        if self.eval_probes_h > 4 * self.config.payload_throttle_probes:
            self.eval_probes_h //= 2
            self.eval_hits_h //= 2

    def store_throttled(self) -> bool:
        """Admission throttle: True once this table has seen many
        evaluation probes at a negligible payload hit rate — storing more
        blocks is then pure overhead (keys don't recur here).  The rate
        is re-checked every call over the decayed window, and the
        executor still stores on an occasional probation fold, so a
        workload shift re-opens storage."""
        cfg = self.config
        return (self.eval_probes_h >= cfg.payload_throttle_probes
                and self.eval_hits_h
                < cfg.payload_throttle_hit_rate * self.eval_probes_h)

    def flush_payloads(self) -> None:
        """Epoch reset of the arena: every payload pointer is invalidated
        (keys and counts stay — count-mode hits are unaffected) and the
        bump pointer rewinds.  Reclaims blocks orphaned by key eviction."""
        if self.pay_len is not None:
            self.pay_len = jnp.full_like(self.pay_len, -1)
        self.slab_bump = 0
        self.payload_flushes += 1

    # -- dynamic sizing (the paper's flexible-cache knob) --------------
    def maybe_resize(self, headroom: Optional[int] = None) -> int:
        """Controller step; returns the slot delta (0 = no change).

        Grow ×2 when the window hit-rate is low *and* the table is mostly
        full (conflict pressure — more slots can actually help); shrink ÷2
        when occupancy stays below the configured floor (memory handed
        back).  ``headroom`` caps growth (global budget minus slots already
        spent elsewhere)."""
        cfg = self.config
        if not cfg.dynamic or self.window_launches < cfg.resize_interval:
            return 0
        probes, hits = (int(x) for x in device_get(
            (self._acc_window_probes, self._acc_window_hits),
            "cache-resize-window"))
        self._acc_window_hits = self._acc_window_probes = 0
        self.window_launches = 0
        if probes == 0:
            return 0
        hit_rate = hits / probes
        occ = self.occupancy() / max(1, self.n_slots)
        old = self.n_slots
        new = old
        if (hit_rate < cfg.grow_below_hit_rate and occ > 0.5
                and old * 2 <= cfg.max_slots):
            new = old * 2
            if headroom is not None:
                new = min(new, old + max(0, headroom))
        elif occ < cfg.shrink_below_occupancy and old // 2 >= cfg.min_slots:
            new = old // 2
        new = (new // cfg.ways) * cfg.ways
        if new <= 0 or new == old:
            return 0
        self._rehash(new)
        self.resizes += 1
        return self.n_slots - old

    def _rehash(self, new_slots: int) -> None:
        old_keys = self.keys.reshape(-1)
        old_vals = self.vals.reshape(-1)
        old_cost = self.cost.reshape(-1)
        old_used = self.used.reshape(-1)
        has_pay = self.pay_off is not None
        if has_pay:
            old_poff = self.pay_off.reshape(-1)
            old_plen = self.pay_len.reshape(-1)
        fresh = DeviceCache.create(self.config, new_slots)
        self.keys, self.vals, self.used, self.stamp, self.cost = (
            fresh.keys, fresh.vals, fresh.used, fresh.stamp, fresh.cost)
        self.pay_off, self.pay_len = fresh.pay_off, fresh.pay_len
        # the slab and its bump pointer survive a resize: offsets stored in
        # the re-inserted metadata still point at live arena rows
        if not bool(device_get(old_used.any(), "cache-rehash")):
            return
        # re-insert resident entries in one batched op; rehash collisions
        # drop entries, which only costs future recomputation (optionality)
        self.tick += 1
        if has_pay:
            out = _insert(
                self.keys, self.vals, self.used, self.stamp, self.cost,
                old_keys, old_vals, old_cost, old_used,
                jnp.int32(self.tick), policy=self.config.policy,
                rounds=min(self.config.ways, 8),
                pay=(self.pay_off, self.pay_len, old_poff, old_plen))
            (self.keys, self.vals, self.used, self.stamp, self.cost,
             self.pay_off, self.pay_len) = out[:7]
        else:
            out = _insert(self.keys, self.vals, self.used, self.stamp,
                          self.cost, old_keys, old_vals, old_cost, old_used,
                          jnp.int32(self.tick), policy=self.config.policy,
                          rounds=min(self.config.ways, 8))
            self.keys, self.vals, self.used, self.stamp, self.cost = out[:5]

    def stats(self) -> Dict[str, int]:
        acc = device_get(
            {"hits": self._acc_hits, "misses": self._acc_misses,
             "probes": self._acc_probes, "inserts": self._acc_inserts,
             "evictions": self._acc_evictions,
             "payload_hits": self._acc_payload_hits,
             "occupancy": jnp.sum(self.used)}, "cache-stats")
        out = {k: int(v) for k, v in acc.items()}
        out["resizes"] = self.resizes
        out["slots"] = self.n_slots
        out["payload_flushes"] = self.payload_flushes
        out["payload_skips"] = self.payload_skips
        out["payload_throttled"] = self.payload_throttled
        out["slab_rows"] = self.slab_bump
        return out

    # -- cross-process state (repro/serve snapshots; DESIGN.md §2.9) ---
    def export_state(self) -> Dict[str, object]:
        """Host copy of everything a fresh process needs to serve hits
        from this table: the key/count planes, the payload metadata +
        slab arena, and the host-side slab epoch (``slab_bump`` and
        ``payload_flushes``).  The epoch scalars are the part a naive
        array-only snapshot loses — without them a loader's allocator
        restarts at row 0 and overwrites resident blocks whose
        ``pay_off``/``pay_len`` still claim those rows (stale splices)."""
        arrays = {"keys": self.keys, "vals": self.vals, "used": self.used,
                  "stamp": self.stamp, "cost": self.cost}
        if self.pay_off is not None:
            arrays["pay_off"] = self.pay_off
            arrays["pay_len"] = self.pay_len
            if self.slab is not None:
                arrays["slab"] = self.slab
        host = device_get(arrays, "cache-export")
        state: Dict[str, object] = {k: np.asarray(v)
                                    for k, v in host.items()}
        state["slab_bump"] = int(self.slab_bump)
        state["payload_flushes"] = int(self.payload_flushes)
        state["tick"] = int(self.tick)
        return state

    def import_state(self, state: Dict[str, object]) -> str:
        """Adopt a previously exported table state.  Returns:

        * ``"ok"``      — keys/counts and (if configured) payloads resident;
        * ``"flushed"`` — keys/counts adopted but the payload region was
          cold-started because the snapshot's slab epoch is unusable
          (missing/mis-shaped slab, or a resident block outside
          ``[0, slab_bump]`` — the stale-splice hazard this method exists
          to close);
        * ``"rejected"`` — state malformed for this config; table unchanged.

        The loaded slot count may differ from ``config.slots`` (the writer
        may have resized); table ops derive their geometry from the array
        shapes, so the arrays are adopted wholesale."""
        try:
            keys = np.asarray(state["keys"], np.int64)
            vals = np.asarray(state["vals"], np.int64)
            used = np.asarray(state["used"], bool)
            stamp = np.asarray(state["stamp"], np.int32)
            cost = np.asarray(state["cost"], np.int64)
        except (KeyError, TypeError, ValueError):
            return "rejected"
        shape = keys.shape
        if (keys.ndim != 2 or shape[1] != self.config.ways
                or any(a.shape != shape
                       for a in (vals, used, stamp, cost))):
            return "rejected"
        # adoption must run under x64 or the int64 key/count planes are
        # silently truncated to int32 (packed adhesion keys would corrupt)
        with enable_x64():
            self.keys = jnp.asarray(keys)
            self.vals = jnp.asarray(vals)
            self.used = jnp.asarray(used)
            self.stamp = jnp.asarray(stamp)
            self.cost = jnp.asarray(cost)
        self.tick = max(self.tick, int(state.get("tick", 0)))
        if not self.config.cache_payloads:
            return "ok"
        status = "ok"
        cap = int(self.config.payload_rows)
        try:
            pay_off = np.asarray(state["pay_off"], np.int32)
            pay_len = np.asarray(state["pay_len"], np.int32)
            bump = int(state["slab_bump"])
            if pay_off.shape != shape or pay_len.shape != shape:
                raise ValueError("payload plane shape mismatch")
            resident = used & (pay_len >= 0)
            if not (0 <= bump <= cap):
                raise ValueError("slab_bump outside the arena")
            if "slab" in state:
                slab = np.asarray(state["slab"], np.int32)
                if slab.ndim != 2 or slab.shape[0] != cap + 1:
                    raise ValueError("slab arena shape mismatch")
            else:
                # writer never materialized an arena — legal only if no
                # entry claims a block
                if resident.any() or bump != 0:
                    raise ValueError("resident blocks but no slab arena")
                slab = None
            if resident.any():
                off = pay_off[resident].astype(np.int64)
                ln = pay_len[resident].astype(np.int64)
                # the slab-epoch invariant: every resident block must lie
                # inside the allocated prefix, else a future alloc would
                # overwrite rows a key still points at (stale splice)
                if (off < 0).any() or ((off + ln) > bump).any():
                    raise ValueError("resident block outside slab epoch")
            with enable_x64():
                self.pay_off = jnp.asarray(pay_off)
                self.pay_len = jnp.asarray(pay_len)
                self.slab = None if slab is None else jnp.asarray(slab)
            self.slab_bump = bump
            self.payload_flushes = int(state.get("payload_flushes", 0))
        except (KeyError, TypeError, ValueError):
            # cold-start the payload region only: keys/counts stay warm
            # (count-mode hits unaffected), blocks re-fill on use
            s, w = shape
            self.pay_off = jnp.zeros((s, w), jnp.int32)
            self.pay_len = jnp.full((s, w), -1, jnp.int32)
            self.slab = None
            self.slab_bump = 0
            self.payload_flushes += 1
            status = "flushed"
        return status


class CacheManager:
    """Per-TD-node DeviceCaches under one global slot budget."""

    def __init__(self, config: CacheConfig):
        self.config = config
        self.tables: Dict[int, DeviceCache] = {}
        # engine hint: how many node tables will eventually exist, so the
        # controller reserves their initial allocations out of the budget
        # instead of letting the first-created table grow into all of it
        self.expected_tables: Optional[int] = None

    @property
    def enabled(self) -> bool:
        return self.config.initial_slots() > 0

    def node_enabled(self, v: int) -> bool:
        en = self.config.enabled_nodes
        return self.enabled and (en is None or v in en)

    def get(self, v: int) -> DeviceCache:
        t = self.tables.get(v)
        if t is None:
            slots = self.config.initial_slots()
            if self.config.budget is not None:
                # node tables are created lazily: cap a newcomer by the
                # remaining headroom so earlier growth cannot spend the
                # whole budget (floor: one set, so the node still caches)
                headroom = self.config.budget - self.total_slots()
                slots = min(slots, max(self.config.ways, headroom))
            t = DeviceCache.create(self.config, slots)
            self.tables[v] = t
        return t

    def total_slots(self) -> int:
        return sum(t.n_slots for t in self.tables.values())

    def maybe_resize(self, v: int) -> int:
        t = self.tables.get(v)
        if t is None:
            return 0
        headroom = None
        if self.config.budget is not None:
            headroom = self.config.budget - self.total_slots()
            if self.expected_tables is not None:
                missing = max(0, self.expected_tables - len(self.tables))
                headroom -= missing * self.config.initial_slots()
        return t.maybe_resize(headroom)

    def stats(self) -> Dict[str, int]:
        agg = {"hits": 0, "misses": 0, "probes": 0, "inserts": 0,
               "evictions": 0, "resizes": 0, "slots": 0, "occupancy": 0,
               "payload_hits": 0, "payload_flushes": 0, "payload_skips": 0,
               "payload_throttled": 0, "slab_rows": 0}
        for t in self.tables.values():
            for k, val in t.stats().items():
                agg[k] = agg.get(k, 0) + val
        return agg

    # -- cross-process state (repro/serve snapshots) -------------------
    def export_state(self) -> Dict[int, Dict[str, object]]:
        """Per-node table states (see :meth:`DeviceCache.export_state`)."""
        return {int(v): t.export_state() for v, t in self.tables.items()}

    def import_state(self, states: Dict[int, Dict[str, object]]
                     ) -> Dict[int, str]:
        """Adopt exported per-node states; nodes disabled under this
        config are skipped.  Returns each node's import status
        (``"ok"``/``"flushed"``/``"rejected"`` — see
        :meth:`DeviceCache.import_state`)."""
        out: Dict[int, str] = {}
        with enable_x64():  # table creation allocates int64 planes
            for v, st in states.items():
                v = int(v)
                if not self.node_enabled(v):
                    out[v] = "skipped"
                    continue
                out[v] = self.get(v).import_state(st)
        return out

"""Columnar trie over a sorted relation (paper §2.4, "cascading vectors").

A trie level i of atom R(v_1..v_k) (variables pre-permuted into the global
order) is simply column i of the lex-sorted tuple matrix restricted to the row
range selected by the bound prefix.  Sibling lists are contiguous sorted
slices, so seek/next are binary searches — this matches the complexity
contract of LFTJ's balanced-tree tries and is the representation the paper's
own YTD implementation uses.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .db import Counters


@dataclass(frozen=True)
class Trie:
    rows: np.ndarray  # (N, k) lex-sorted unique

    @property
    def num_rows(self) -> int:
        return self.rows.shape[0]

    @property
    def arity(self) -> int:
        return self.rows.shape[1]

    def full_range(self) -> Tuple[int, int]:
        return (0, self.num_rows)

    def column(self, level: int, lo: int, hi: int) -> np.ndarray:
        return self.rows[lo:hi, level]

    def equal_range(self, level: int, lo: int, hi: int, value: int,
                    counters: Optional[Counters] = None) -> Tuple[int, int]:
        """Sub-range of rows whose ``level`` column equals ``value``."""
        col = self.rows[lo:hi, level]
        if counters is not None:
            counters.count_seek(hi - lo)
            counters.count_seek(hi - lo)
        s = int(np.searchsorted(col, value, side="left"))
        e = int(np.searchsorted(col, value, side="right"))
        return lo + s, lo + e

    def seek(self, level: int, lo: int, hi: int, value: int,
             counters: Optional[Counters] = None,
             ) -> Optional[Tuple[int, int, int]]:
        """Leapfrog seek: least value' >= value in the sibling list; returns
        (value', lo', hi') or None when exhausted."""
        col = self.rows[lo:hi, level]
        if counters is not None:
            counters.count_seek(hi - lo)
        s = int(np.searchsorted(col, value, side="left"))
        if s == col.shape[0]:
            return None
        v = int(col[s])
        if counters is not None:
            counters.count_scan()
            counters.count_seek(hi - lo)
        e = int(np.searchsorted(col, v, side="right"))
        return v, lo + s, lo + e

    def distinct_values(self, level: int, lo: int, hi: int,
                        counters: Optional[Counters] = None) -> np.ndarray:
        col = self.rows[lo:hi, level]
        if col.shape[0] == 0:
            return col
        mask = np.empty(col.shape[0], dtype=bool)
        mask[0] = True
        np.not_equal(col[1:], col[:-1], out=mask[1:])
        vals = col[mask]
        if counters is not None:
            counters.count_scan(int(vals.shape[0]))
        return vals


@dataclass
class AtomTrie:
    """Binding of one atom to a trie consistent with a global variable order.

    ``var_order``: the atom's variables sorted by global order position —
    trie level j corresponds to ``var_order[j]``.  Repeated variables inside
    an atom are handled by pre-filtering rows to equality and dropping the
    duplicate columns (so levels always bind distinct variables).
    """

    atom_vars: Tuple[str, ...]
    trie: Trie
    var_order: Tuple[str, ...]

    @staticmethod
    def build(db, relation: str, atom_vars: Sequence[str],
              global_order: Sequence[str]) -> "AtomTrie":
        pos = {x: i for i, x in enumerate(global_order)}
        uniq: List[str] = []
        first_col = {}
        for c, v in enumerate(atom_vars):
            if v not in first_col:
                first_col[v] = c
                uniq.append(v)
        ordered = tuple(sorted(uniq, key=lambda v: pos[v]))
        rows = db.relations[relation]
        # repeated-variable filter (e.g. E(x, x))
        for c, v in enumerate(atom_vars):
            if first_col[v] != c:
                rows = rows[rows[:, c] == rows[:, first_col[v]]]
        perm = [first_col[v] for v in ordered]
        sorted_rows = db.sorted_view(relation, perm) if rows is db.relations[relation] \
            else _sort_rows(rows[:, perm])
        return AtomTrie(tuple(atom_vars), Trie(sorted_rows), ordered)

    def level_of(self, var: str) -> int:
        return self.var_order.index(var)


def _sort_rows(rows: np.ndarray) -> np.ndarray:
    if rows.shape[0] == 0:
        return rows
    return np.unique(rows, axis=0)


def leapfrog_intersection(
        iters: List[Tuple[Trie, int, int, int]],
        counters: Optional[Counters] = None,
) -> Iterator[Tuple[int, List[Tuple[int, int]]]]:
    """Leapfrog join of the sibling lists of several tries (paper §2.4).

    ``iters``: per atom (trie, level, lo, hi).  Yields (value, per-atom
    equal-ranges).  The classic discipline — the iterator with the least head
    seeks to the running maximum — is preserved; seeks are galloping binary
    searches whose cost is logged into ``counters``.
    """
    k = len(iters)
    assert k >= 1
    heads: List[Tuple[int, int, int]] = []  # (value, lo', hi') per atom
    x = None
    for trie, level, lo, hi in iters:
        got = trie.seek(level, lo, hi, -(2 ** 62), counters)
        if got is None:
            return
        heads.append(got)
        x = got[0] if x is None else max(x, got[0])
    while True:
        # align all iterators on x
        aligned = 0
        i = 0
        while aligned < k:
            v, s, e = heads[i]
            if v == x:
                aligned += 1
            else:  # v < x: seek forward
                trie, level, lo, hi = iters[i]
                got = trie.seek(level, s, hi, x, counters)
                if got is None:
                    return
                heads[i] = got
                if got[0] > x:
                    x = got[0]
                    aligned = 1
                else:
                    aligned += 1
            i = (i + 1) % k
        yield x, [(s, e) for (_, s, e) in heads]
        # advance: next distinct value after x on iterator 0
        trie, level, lo, hi = iters[0]
        got = trie.seek(level, heads[0][2], hi, x + 1, counters)
        if got is None:
            return
        heads[0] = got
        x = got[0]

"""AdamW with cosine schedule, global-norm clipping, sharded states.

Optimizer states mirror parameter shapes (and therefore parameter
shardings); everything is a pure function so the whole update jits/lowers
inside train_step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_state(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, params, grads, state,
           ) -> Tuple[Dict, Dict, Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p - lr * step_dir).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}

"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every parameter leaf carries logical axis names from its ``ParamSpec``; the
rules below map them to mesh axes.  A mapping is applied only when the mesh
axes exist *and* the dimension is divisible by their total size — otherwise
the dimension is replicated (e.g. whisper's 6 heads or vocab 51865 on a
16-way model axis).  This keeps a single rule set valid for every assigned
architecture on every mesh.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisTarget = Union[None, str, Tuple[str, ...]]

# parameter logical axis -> mesh axes
DEFAULT_RULES: Dict[str, AxisTarget] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "rnn": "model",
    "embed": None,
    "embed_out": None,
    "head_dim": None,
    "layers": None,
    "conv": None,
    "rnn_in": None,
}

# activation logical axis -> mesh axes
ACT_RULES: Dict[str, AxisTarget] = {
    "batch": ("pod", "data"),
    "seq": None,                # sequence parallelism is a perf-pass option
    "kv_seq": "model",          # decode caches: shard the cache depth over
                                # model (kv_heads <= 8 never divide 16)
    "act_embed": None,
    "act_heads": "model",
    "act_kv": "model",
    "act_vocab": "model",
    "img": None,
}

# ZeRO-3/FSDP training rules: weights & optimizer states additionally shard
# their 'embed'-like dims over the data(+pod) axes; GSPMD materializes the
# per-layer all-gather (fwd/bwd) + reduce-scatter (grads) pattern.
FSDP_RULES = dict(DEFAULT_RULES,
                  embed=("pod", "data"),
                  rnn_in=("pod", "data"),
                  embed_out="model")

# Output-dim MoE ZeRO-3: shard expert FFN width (mlp) over data instead of
# the contracting embed dim — avoids GSPMD's flop-replicating strategies on
# the expert einsums (wo still pays; see §Perf).
MOE_FSDP_OUTDIM = dict(DEFAULT_RULES, mlp=("pod", "data"))

# Expert-data serving rules (§Perf): shard the expert axis over 'data'
# instead of ZeRO-gathering weights — tokens travel (all-to-all), weights
# stay resident.  For giant-MoE serving the token exchange is orders of
# magnitude smaller than per-step weight gathering.
MOE_SERVE_RULES = dict(DEFAULT_RULES, expert=("pod", "data"))


def _mesh_axes(mesh: Mesh, target: AxisTarget) -> Tuple[str, ...]:
    if target is None:
        return ()
    axes = (target,) if isinstance(target, str) else tuple(target)
    return tuple(a for a in axes if a in mesh.axis_names)


def partition_spec(logical: Sequence[Optional[str]],
                   shape: Sequence[int], mesh: Mesh,
                   rules: Optional[Dict[str, AxisTarget]] = None) -> P:
    rules = {**DEFAULT_RULES, **ACT_RULES, **(rules or {})}
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        target: AxisTarget = rules.get(name) if name else None
        axes = _mesh_axes(mesh, target) if target is not None else ()
        axes = tuple(a for a in axes if a not in used)
        total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if axes and dim % total == 0 and total > 1:
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        else:
            parts.append(None)
    return P(*parts)


def named_sharding(mesh: Mesh, logical: Sequence[Optional[str]],
                   shape: Sequence[int],
                   rules: Optional[Dict[str, AxisTarget]] = None,
                   ) -> NamedSharding:
    return NamedSharding(mesh, partition_spec(logical, shape, mesh, rules))


def tree_shardings(mesh: Mesh, logical_tree, shape_tree,
                   rules: Optional[Dict[str, AxisTarget]] = None):
    """Shardings for a pytree of (logical axes, shapes)."""
    return jax.tree.map(
        lambda lg, sh: named_sharding(mesh, lg, sh.shape, rules),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def batch_spec(mesh: Mesh) -> P:
    axes = _mesh_axes(mesh, ("pod", "data"))
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def batch_sharding(mesh: Mesh, batch_size: int) -> NamedSharding:
    axes = _mesh_axes(mesh, ("pod", "data"))
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch_size % total == 0:
        return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))
    # small batches (e.g. long_500k B=1): replicate
    return NamedSharding(mesh, P())


def constrain_batch(x, mesh: Mesh):
    """Activation constraint: shard the leading batch dim."""
    spec = batch_spec(mesh)
    ndim = x.ndim
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*(list(spec) + [None] * (ndim - 1)))))

"""Version-compat shims for the ``jax.tree`` namespace.

``jax.tree.flatten_with_path`` / ``jax.tree.map_with_path`` only exist in
newer jax releases; older ones (e.g. 0.4.37, this image) expose the same
functions under ``jax.tree_util``.  Import the path-aware helpers from here
so every module works on either side of the rename.
"""
from __future__ import annotations

import jax

try:
    tree_flatten_with_path = jax.tree.flatten_with_path
except AttributeError:
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path

try:
    tree_map_with_path = jax.tree.map_with_path
except AttributeError:
    tree_map_with_path = jax.tree_util.tree_map_with_path

"""Attention dispatch: XLA flash-scan (default on CPU / in the dry-run),
Pallas kernel (TPU target, interpret-validated), naive reference (tests).

The XLA path is a blockwise online-softmax identical in structure to the
Pallas kernel (double lax.scan over q/kv blocks), so its memory stays
O(T·block) — required for the 32k-prefill dry-run cells to fit — and XLA's
cost analysis sees the same FLOPs the kernel would execute.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as fa
from . import ref

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "unroll"))
def flash_attention_xla(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, q_offset: int = 0,
                        block_q: int = 512, block_k: int = 1024,
                        unroll: bool = False):
    b, t, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    block_q = min(block_q, t)
    block_k = min(block_k, s)
    nq = -(-t // block_q)
    nk = -(-s // block_k)
    tp, sp = nq * block_q, nk * block_k
    qg = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0))) \
        .reshape(b, nq, block_q, hkv, g, dh).astype(jnp.float32)
    kg = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0))) \
        .reshape(b, nk, block_k, hkv, dh).astype(jnp.float32) \
        .transpose(1, 0, 2, 3, 4)       # (nk, B, BK, Hkv, Dh) for lax.scan
    vg = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0))) \
        .reshape(b, nk, block_k, hkv, dh).astype(jnp.float32) \
        .transpose(1, 0, 2, 3, 4)
    scale = 1.0 / (dh ** 0.5)

    def q_step(_, qi):
        qblk, qidx = qi                      # (B, BQ, Hkv, G, Dh)
        qpos = q_offset + qidx * block_q + jnp.arange(block_q)

        @jax.checkpoint
        def kv_step(carry, kv):
            m_p, l_p, acc = carry
            kblk, vblk, kidx = kv
            kpos = kidx * block_k + jnp.arange(block_k)
            sc = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk) * scale
            mask = (kpos[None, :] < s)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_c = jnp.maximum(m_p, sc.max(-1))
            alpha = jnp.exp(m_p - m_c)
            p = jnp.exp(sc - m_c[..., None])
            l_c = l_p * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk)
            return (m_c, l_c, acc), None

        init = (jnp.full((b, hkv, g, block_q), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, g, block_q), jnp.float32),
                jnp.zeros((b, hkv, g, block_q, dh), jnp.float32))
        if unroll:   # cost-probe mode (launch/costprobe.py)
            carry = init
            for j in range(nk):
                carry, _ = kv_step(carry, (kg[j], vg[j], jnp.asarray(j)))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, init, (kg, vg, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,Hkv,G,BQ,Dh)
        return None, out

    # remat both scan bodies: backward recomputes (BQ, BK) score blocks
    # instead of saving the full (T, S) score tensor — the flash property
    # must survive autodiff, not just the forward pass.
    qg_t = qg.transpose(1, 0, 2, 3, 4, 5)
    if unroll:
        blocks = jnp.stack([q_step(None, (qg_t[i], jnp.asarray(i)))[1]
                            for i in range(nq)])
    else:
        _, blocks = jax.lax.scan(jax.checkpoint(q_step), None,
                                 (qg_t, jnp.arange(nq)))
    # blocks: (nq, B, Hkv, G, BQ, Dh) -> (B, T, H, Dh)
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(b, tp, h, dh)[:, :t]
    return out.astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    impl: str = "xla", **kw):
    if impl == "xla_unroll":
        # cost-probe mode: big blocks (identical FLOPs, far fewer inlined
        # block bodies — compile time at 32k prefill would explode at the
        # production 512-block tiling)
        kw.setdefault("block_q", 4096)
        kw.setdefault("block_k", 4096)
        return flash_attention_xla(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, unroll=True, **kw)
    if impl == "xla":
        return flash_attention_xla(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, **kw)
    if impl == "pallas":
        return fa.flash_attention_pallas(q, k, v, causal=causal,
                                         window=window, q_offset=q_offset,
                                         **kw)
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    raise ValueError(impl)

"""Pure-jnp oracle for flash attention (naive full-matrix softmax attention).

Shapes: q (B, T, H, Dh); k, v (B, S, Hkv, Dh) with H % Hkv == 0 (GQA).
``window``: optional sliding-window size W — query at absolute position p
may attend to keys in (p - W, p] (plus causality).  ``q_offset`` gives the
absolute position of q[0] (decode / chunked prefill).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    b, t, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    assert h % hkv == 0
    g = h // hkv
    qq = q.reshape(b, t, hkv, g, dh).astype(jnp.float32)
    kk = k.astype(jnp.float32)
    vv = v.astype(jnp.float32)
    scores = jnp.einsum("bthgd,bshd->bhgts", qq, kk) / jnp.sqrt(dh)
    qpos = q_offset + jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jnp.softmax(scores, axis=-1) if hasattr(jnp, "softmax") else \
        jnp.exp(scores - scores.max(-1, keepdims=True)) / \
        jnp.exp(scores - scores.max(-1, keepdims=True)).sum(-1, keepdims=True)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, vv)
    return out.reshape(b, t, h, dh).astype(q.dtype)

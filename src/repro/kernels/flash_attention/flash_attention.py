"""Pallas TPU flash attention (causal / sliding-window, GQA).

Classic FlashAttention-2 schedule adapted to the TPU grid model: the grid is
(batch·kv_head, q_blocks, kv_blocks) with the kv dimension iterated
sequentially (TPU grids execute minor-to-major in order), so the running
max/sum/accumulator live in VMEM scratch across kv steps.  Blocks are
(BQ, Dh) / (BK, Dh) tiles; Dh (128 for every assigned arch) is already a
lane multiple.

The q tensor is pre-reshaped to (B·Hkv, G, T, Dh) — grouped-query heads ride
in the G dimension of the block so each kv block is loaded once per group.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_k: int, causal: bool,
                  window: Optional[int], q_offset: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]              # (G, BQ, Dh)
    k = k_ref[0]              # (BK, Dh)
    v = v_ref[0]              # (BK, Dh)
    s = jnp.einsum("gqd,kd->gqk", q.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_q, block_k), 1)
    kpos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_q, block_k), 2)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (G, BQ)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[..., None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "gqk,kd->gqd", p, v.astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kj == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           q_offset: int = 0,
                           block_q: int = DEFAULT_BQ,
                           block_k: int = DEFAULT_BK,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, T, H, Dh); k/v: (B, S, Hkv, Dh).  Returns (B, T, H, Dh)."""
    b, t, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    assert h % hkv == 0
    g = h // hkv
    block_q = min(block_q, t)
    block_k = min(block_k, s)
    # (B*Hkv, G, T, Dh) so one kv block serves the whole query group
    qg = q.reshape(b, t, hkv, g, dh).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv, g, t, dh)
    kg = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, dh)
    vg = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, dh)
    grid = (b * hkv, pl.cdiv(t, block_q), pl.cdiv(s, block_k))
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_k=s,
        causal=causal, window=window, q_offset=q_offset,
        scale=1.0 / (dh ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, block_q, dh),
                         lambda bh, qi, kj: (bh, 0, qi, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, qi, kj: (bh, kj, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, qi, kj: (bh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, block_q, dh),
                               lambda bh, qi, kj: (bh, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, t, dh), q.dtype),
        scratch_shapes=[
            # (G, BQ) running max / sum and (G, BQ, Dh) accumulator in VMEM
            pltpu.VMEM((g, block_q), jnp.float32),
            pltpu.VMEM((g, block_q), jnp.float32),
            pltpu.VMEM((g, block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(b, hkv, g, t, dh).transpose(0, 3, 1, 2, 4) \
        .reshape(b, t, h, dh)

# Device-kernel layer.  Entry-point convention: every kernel is reached
# through kernels/registry.py (dispatch + autotune + fallback); each
# kernel package keeps <name>.py / ref.py where ref.py is the pure
# oracle its implementations are validated against.
#   expand/    — fused frontier expansion (fused Pallas | XLA chain)
#   leapfrog/  — batched bounded lower/upper bound (Pallas dense count)
#   flash_attention/ — LM-substrate attention (own ops.py facade)

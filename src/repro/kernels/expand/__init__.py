"""Fused-EXPAND kernel subsystem (DESIGN.md §2.7).

One ``EXPAND(d)`` frontier expansion as a device kernel, three ways:

  * ``fused.py`` — the single-pass Pallas kernel (compiled on TPU/GPU,
    interpret mode on CPU);
  * ``xla.py``   — the jnp op chain XLA fuses piecewise (the
    always-available fallback, and the former ``core/frontier`` step);
  * ``ref.py``   — the plain-numpy oracle both are validated against.

Reach implementations through ``kernels.registry`` (``expand_fn``), never
directly — dispatch, autotune, and fallback live there.
"""
from .fused import FusedExpandConfig
from .ref import expand_ref

__all__ = ["FusedExpandConfig", "expand_ref"]

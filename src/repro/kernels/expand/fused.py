"""Fused-EXPAND Pallas kernel: one frontier expansion in a single launch.

The XLA chain (``xla.py``) materializes ~6 intermediate HBM arrays per
participating atom per ``EXPAND(d)`` — guard-run enumeration, two
``searchsorted`` results per atom, the mask, and the compaction permute
each round-trip through memory.  This kernel performs the whole step —

  1. **plan**: per-row guard run range (bounded binary search over the
     run-start array), candidate counts, exclusive-cumsum slot offsets,
     and the ``needed`` total;
  2. **expand**: per output slot, invert the offset map (upper-bound
     search), gather the candidate value and its run window, and verify
     membership in every other participating atom with two bounded
     binary searches, narrowing that atom's [lo, hi) trie window;
  3. **compact**: inclusive-scan the survivor mask and gather the j-th
     surviving row into output slot j (a stable partition computed as a
     dest-side lower-bound search — no sort primitive needed);

— in ONE ``pallas_call``, staging intermediates in VMEM scratch instead
of HBM.  The wrapper is ≤2 device ops per EXPAND: the launch plus the
``needed`` scalar extraction (`bench_expand_kernel` pins this).

**Grid/blocking.**  ``grid = (2, C // block_q)``: the slower axis is the
phase (expand, then compact — TPU grids iterate sequentially, so phase 1
sees phase 0's scratch), the faster axis tiles the chunk's output slots
so per-iteration vector work stays inside a VMEM-sized window.  Trie
columns and the parent chunk are resident across iterations (constant
index maps); the frontier ``capacity`` therefore bounds the working set,
exactly as it bounds device memory for the rest of the engine.  The plan
and scan sub-steps run once each (first iteration of their phase) into
scratch shared by the later tiles.

**Dispatch/testing story** (DESIGN.md §2.7): compiled on TPU/GPU,
interpret mode on CPU — where it is exercised by the conformance zoo
with ``expand_kernel="pallas"`` forced (bit-exact against the XLA chain
on the valid prefix; invalid tail rows are garbage in both paths, only
their ``valid=False`` is contractual).  Outputs match the XLA chain's
compaction exactly: same survivor order (both are stable), same
``needed``.  The registry falls back to the XLA chain if this kernel
fails to build on a backend.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["FusedExpandConfig", "build"]

DEFAULT_BLOCK_Q = 1024


@dataclass(frozen=True)
class FusedExpandConfig:
    """Grid/block-size knobs for the fused kernel.

    ``block_q`` — output slots per grid iteration (snapped to a divisor
    of the chunk capacity); ``interpret`` — force the Pallas interpreter
    (None = auto: interpret everywhere except TPU/GPU)."""

    block_q: int = DEFAULT_BLOCK_Q
    interpret: Optional[bool] = None

    def resolve_block_q(self, capacity: int) -> int:
        return math.gcd(capacity, min(self.block_q, capacity))

    def resolve_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() not in ("tpu", "gpu")


def _search(col, values, lo, hi, *, strict: bool):
    """Branchless fixed-trip bounded binary search on in-register values
    (the in-kernel twin of ``registry._bsearch`` — same trip count, same
    insertion-point semantics, so results are bit-identical)."""
    n = col.shape[0]
    if n == 0:
        return lo
    trips = max(1, int(math.ceil(math.log2(n + 1))) + 1)

    def body(_, lh):
        lo_, hi_ = lh
        go = lo_ < hi_
        mid = (lo_ + hi_) >> 1
        x = col[jnp.clip(mid, 0, n - 1)]
        pred = (x < values) if strict else (x <= values)
        return (jnp.where(go & pred, mid + 1, lo_),
                jnp.where(go & ~pred, mid, hi_))

    lo_, _ = jax.lax.fori_loop(0, trips, body, (lo, hi))
    return lo_


def _make_kernel(*, C: int, d: int, g_ai: int, other_ais: Tuple[int, ...],
                 nruns: int, n_rows_g: int, block_q: int):
    n_others = len(other_ais)
    i32 = jnp.int32

    def kernel(*refs):
        (assign_ref, factor_ref, valid_ref, orig_ref, lo_ref, hi_ref,
         gcol_ref, grs_ref) = refs[:8]
        other_refs = refs[8:8 + n_others]
        (o_assign, o_factor, o_valid, o_orig, o_lo, o_hi,
         o_needed) = refs[8 + n_others:15 + n_others]
        (s_r0, s_cnt, s_off, s_ok, s_csum, s_assign, s_factor, s_orig,
         s_lo, s_hi) = refs[15 + n_others:]

        phase = pl.program_id(0)
        j = pl.program_id(1)
        base = j * block_q
        zeros_c = jnp.zeros((C,), i32)

        @pl.when((phase == 0) & (j == 0))
        def _plan():
            grs = grs_ref[...]
            r0 = _search(grs, lo_ref[...][:, g_ai], zeros_c,
                         jnp.full((C,), nruns, i32), strict=True)
            r1 = _search(grs, hi_ref[...][:, g_ai], zeros_c,
                         jnp.full((C,), nruns, i32), strict=True)
            cnt = jnp.where(valid_ref[...], r1 - r0, 0).astype(i32)
            off = (jnp.cumsum(cnt) - cnt).astype(i32)
            s_r0[...] = r0.astype(i32)
            s_cnt[...] = cnt
            s_off[...] = off
            o_needed[0] = off[C - 1] + cnt[C - 1]

        @pl.when(phase == 0)
        def _expand():
            slots = base + jax.lax.iota(i32, block_q)
            off, cnt = s_off[...], s_cnt[...]
            needed = off[C - 1] + cnt[C - 1]
            src = _search(off, slots, jnp.zeros((block_q,), i32),
                          jnp.full((block_q,), C, i32), strict=False) - 1
            src = jnp.clip(src, 0, C - 1)
            delta = slots - off[src]
            ok = (slots < needed) & (delta < cnt[src])
            k = jnp.clip(s_r0[...][src] + delta, 0, nruns - 1)
            grs = grs_ref[...]
            pos = grs[k]
            value = gcol_ref[...][jnp.clip(pos, 0, max(n_rows_g - 1, 0))]
            run_end = jnp.where(k + 1 < nruns,
                                grs[jnp.clip(k + 1, 0, nruns - 1)],
                                n_rows_g).astype(i32)
            lo_full, hi_full = lo_ref[...], hi_ref[...]
            lo2 = lo_full[src].at[:, g_ai].set(pos)
            hi2 = hi_full[src].at[:, g_ai].set(run_end)
            for ai, col_ref in zip(other_ais, other_refs):
                col = col_ref[...]
                s = _search(col, value, lo_full[src, ai], hi_full[src, ai],
                            strict=True)
                e = _search(col, value, s, hi_full[src, ai], strict=False)
                ok = ok & (s < e)
                lo2 = lo2.at[:, ai].set(s.astype(i32))
                hi2 = hi2.at[:, ai].set(e.astype(i32))
            blk = pl.ds(base, block_q)
            s_assign[blk, :] = assign_ref[...][src].at[:, d].set(
                value.astype(i32))
            s_factor[blk] = factor_ref[...][src]
            s_orig[blk] = orig_ref[...][src]
            s_lo[blk, :] = lo2.astype(i32)
            s_hi[blk, :] = hi2.astype(i32)
            s_ok[blk] = ok.astype(i32)

        @pl.when((phase == 1) & (j == 0))
        def _scan():
            s_csum[...] = jnp.cumsum(s_ok[...]).astype(i32)

        @pl.when(phase == 1)
        def _compact():
            dest = base + jax.lax.iota(i32, block_q)
            csum = s_csum[...]
            # stable partition as a gather: output slot j takes the j-th
            # surviving staged row = first index with csum == j+1
            t = _search(csum, dest + 1, jnp.zeros((block_q,), i32),
                        jnp.full((block_q,), C, i32), strict=True)
            t = jnp.clip(t, 0, C - 1)
            o_assign[...] = s_assign[...][t]
            o_factor[...] = s_factor[...][t]
            o_valid[...] = dest < csum[C - 1]
            o_orig[...] = s_orig[...][t]
            o_lo[...] = s_lo[...][t]
            o_hi[...] = s_hi[...][t]

    return kernel


def build(*, d: int, g_ai: int, other_ais: Tuple[int, ...], n_rows_g: int,
          g_col, g_rs, other_cols, config: Optional[FusedExpandConfig] = None):
    """Close the per-depth arrays over the fused kernel → fn(F) ->
    (F', needed), jitted (the pallas_call is (re)constructed at trace
    time from the chunk's shapes/dtypes, so one built fn serves x64 on
    and off)."""
    config = config or FusedExpandConfig()
    nruns = int(g_rs.shape[0])
    assert nruns > 0 and n_rows_g > 0, \
        "degenerate guard tries take the XLA path (registry dispatch)"

    @jax.jit
    def fn(F):
        C, n_vars = F.assign.shape
        m = F.lo.shape[1]
        block_q = config.resolve_block_q(C)
        nb = C // block_q
        kernel = _make_kernel(C=C, d=d, g_ai=g_ai, other_ais=other_ais,
                              nruns=nruns, n_rows_g=n_rows_g,
                              block_q=block_q)
        full = lambda shape: pl.BlockSpec(shape, lambda p, j: (0,) * len(shape))
        tile1 = pl.BlockSpec((block_q,), lambda p, j: (j,))
        tile2 = lambda w: pl.BlockSpec((block_q, w), lambda p, j: (j, 0))
        outs = pl.pallas_call(
            kernel,
            grid=(2, nb),
            in_specs=[
                full((C, n_vars)), full((C,)), full((C,)), full((C,)),
                full((C, m)), full((C, m)),
                full((n_rows_g,)), full((nruns,)),
                *[full((int(c.shape[0]),)) for c in other_cols],
            ],
            out_specs=[
                tile2(n_vars), tile1, tile1, tile1, tile2(m), tile2(m),
                pl.BlockSpec((1,), lambda p, j: (0,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((C, n_vars), F.assign.dtype),
                jax.ShapeDtypeStruct((C,), F.factor.dtype),
                jax.ShapeDtypeStruct((C,), jnp.bool_),
                jax.ShapeDtypeStruct((C,), F.orig.dtype),
                jax.ShapeDtypeStruct((C, m), F.lo.dtype),
                jax.ShapeDtypeStruct((C, m), F.hi.dtype),
                jax.ShapeDtypeStruct((1,), jnp.int32),
            ],
            scratch_shapes=[
                pltpu.VMEM((C,), jnp.int32),            # s_r0
                pltpu.VMEM((C,), jnp.int32),            # s_cnt
                pltpu.VMEM((C,), jnp.int32),            # s_off
                pltpu.VMEM((C,), jnp.int32),            # s_ok
                pltpu.VMEM((C,), jnp.int32),            # s_csum
                pltpu.VMEM((C, n_vars), F.assign.dtype),  # s_assign
                pltpu.VMEM((C,), F.factor.dtype),       # s_factor
                pltpu.VMEM((C,), F.orig.dtype),         # s_orig
                pltpu.VMEM((C, m), F.lo.dtype),         # s_lo
                pltpu.VMEM((C, m), F.hi.dtype),         # s_hi
            ],
            interpret=config.resolve_interpret(),
        )(F.assign, F.factor, F.valid, F.orig, F.lo, F.hi,
          g_col, g_rs, *other_cols)
        o_assign, o_factor, o_valid, o_orig, o_lo, o_hi, o_needed = outs
        return F._replace(assign=o_assign, factor=o_factor, valid=o_valid,
                          orig=o_orig, lo=o_lo, hi=o_hi), o_needed[0]

    return fn

"""The XLA-op EXPAND path: one frontier expansion as a jnp op chain.

This is the expansion step that used to live in ``core/frontier.py``
(DESIGN.md §2.1), relocated behind the kernel registry so every EXPAND
implementation shares one entry-point convention.  Semantics are the
contract the fused Pallas kernel (``fused.py``) is held to, and both are
validated against the plain-numpy oracle in ``ref.py``:

* enumerate each valid row's guard candidate runs (searchsorted over the
  run-start array), lay the (row, candidate) pairs out over output slots
  via cumsum + searchsorted;
* verify each candidate's membership in every other participating atom
  with bounded binary search (two per atom), narrowing that atom's
  [lo, hi) trie window;
* compact surviving rows to the front of the chunk (stable partition).

XLA materializes ~6 intermediate arrays per participating atom here — the
memory-traffic motivation for the fused kernel.  The functions are generic
over any Frontier-shaped NamedTuple (assign/factor/valid/orig/lo/hi).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ..registry import lower_bound, upper_bound

__all__ = ["build", "expand_step", "compact"]


@jax.jit
def compact(F):
    """Stable-partition valid rows to the front of the chunk."""
    perm = jnp.argsort(jnp.logical_not(F.valid), stable=True)
    return type(F)(*(x[perm] for x in F))


@functools.partial(
    jax.jit,
    static_argnames=("d", "g_ai", "other_ais", "n_rows_g", "impl"))
def expand_step(F, g_col, g_rs, other_cols, *, d: int, g_ai: int,
                other_ais: Tuple[int, ...], n_rows_g: int, impl: str):
    """One frontier expansion (module-level so the jit cache is shared by
    every engine instance with the same query structure / array shapes)."""
    C = F.assign.shape[0]
    nruns = g_rs.shape[0]
    r0 = jnp.searchsorted(g_rs, F.lo[:, g_ai], side="left")
    r1 = jnp.searchsorted(g_rs, F.hi[:, g_ai], side="left")
    counts = jnp.where(F.valid, r1 - r0, 0).astype(jnp.int32)
    offsets = jnp.cumsum(counts) - counts               # exclusive
    needed = offsets[-1] + counts[-1]
    slot = jnp.arange(C, dtype=jnp.int32)
    src = jnp.searchsorted(offsets, slot, side="right") - 1
    src = jnp.clip(src, 0, C - 1)
    delta = slot - offsets[src]
    ok = (slot < needed) & (delta < counts[src])
    if nruns:
        k = jnp.clip(r0[src] + delta, 0, nruns - 1)
        pos = g_rs[k]
        value = g_col[jnp.clip(pos, 0, max(n_rows_g - 1, 0))]
        run_end = jnp.where(k + 1 < nruns,
                            g_rs[jnp.clip(k + 1, 0, nruns - 1)],
                            n_rows_g).astype(jnp.int32)
    else:
        k = jnp.zeros_like(slot)
        pos = jnp.zeros_like(slot)
        value = jnp.zeros_like(slot)
        run_end = jnp.zeros_like(slot)
        ok = ok & False
    lo2 = F.lo[src].at[:, g_ai].set(pos)
    hi2 = F.hi[src].at[:, g_ai].set(run_end)
    for ai, col in zip(other_ais, other_cols):
        s = lower_bound(col, value, F.lo[src, ai], F.hi[src, ai], impl=impl)
        e = upper_bound(col, value, s, F.hi[src, ai], impl=impl)
        ok = ok & (s < e)
        lo2 = lo2.at[:, ai].set(s.astype(jnp.int32))
        hi2 = hi2.at[:, ai].set(e.astype(jnp.int32))
    assign2 = F.assign[src].at[:, d].set(value.astype(jnp.int32))
    out = F._replace(assign=assign2, factor=F.factor[src], valid=ok,
                     orig=F.orig[src], lo=lo2.astype(jnp.int32),
                     hi=hi2.astype(jnp.int32))
    return compact(out), needed


def build(*, d: int, g_ai: int, other_ais: Tuple[int, ...], n_rows_g: int,
          impl: str, g_col, g_rs, other_cols):
    """Close the per-depth arrays over :func:`expand_step` → fn(F)."""

    def fn(F):
        return expand_step(F, g_col, g_rs, other_cols, d=d, g_ai=g_ai,
                           other_ais=other_ais, n_rows_g=n_rows_g, impl=impl)

    return fn

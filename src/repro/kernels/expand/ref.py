"""Plain-numpy oracle for one EXPAND(d) step.

Scalar-loop enumeration with ``np.searchsorted`` — obviously correct and
completely independent of both device implementations (the jnp op chain
in ``xla.py`` and the fused Pallas kernel in ``fused.py``), which the
parity tests validate against it.  Returns only the *valid* output rows
(in stable enumeration order — the prefix both device paths compact to)
plus the ``needed`` slot total; invalid tail rows are not part of the
expansion contract.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

__all__ = ["expand_ref"]


def expand_ref(host: Dict[str, np.ndarray], g_col: np.ndarray,
               g_rs: np.ndarray, other_cols, *, d: int, g_ai: int,
               other_ais: Tuple[int, ...], n_rows_g: int,
               ) -> Tuple[Dict[str, np.ndarray], int]:
    """``host`` is a chunk as numpy (``Frontier._asdict`` fetched).
    Returns ``(rows, needed)`` where ``rows`` holds the surviving rows'
    assign/factor/orig/lo/hi stacked in output order."""
    out = {k: [] for k in ("assign", "factor", "orig", "lo", "hi")}
    needed = 0
    nruns = len(g_rs)
    for i in range(host["valid"].shape[0]):
        if not host["valid"][i]:
            continue
        r0 = int(np.searchsorted(g_rs, host["lo"][i, g_ai], side="left"))
        r1 = int(np.searchsorted(g_rs, host["hi"][i, g_ai], side="left"))
        needed += r1 - r0
        for k in range(r0, r1):
            pos = int(g_rs[k])
            value = int(g_col[pos])
            run_end = int(g_rs[k + 1]) if k + 1 < nruns else n_rows_g
            lo2, hi2 = host["lo"][i].copy(), host["hi"][i].copy()
            lo2[g_ai], hi2[g_ai] = pos, run_end
            ok = True
            for ai, col in zip(other_ais, other_cols):
                w0, w1 = int(host["lo"][i, ai]), int(host["hi"][i, ai])
                s = w0 + int(np.searchsorted(col[w0:w1], value, side="left"))
                e = w0 + int(np.searchsorted(col[w0:w1], value, side="right"))
                if not s < e:
                    ok = False
                    break
                lo2[ai], hi2[ai] = s, e
            if not ok:
                continue
            assign2 = host["assign"][i].copy()
            assign2[d] = value
            out["assign"].append(assign2)
            out["factor"].append(host["factor"][i])
            out["orig"].append(host["orig"][i])
            out["lo"].append(lo2)
            out["hi"].append(hi2)
    n = len(out["assign"])
    rows = {
        "assign": (np.stack(out["assign"]) if n
                   else np.zeros((0, host["assign"].shape[1]), np.int32)),
        "factor": np.asarray(out["factor"], host["factor"].dtype),
        "orig": np.asarray(out["orig"], np.int32),
        "lo": (np.stack(out["lo"]) if n
               else np.zeros((0, host["lo"].shape[1]), np.int32)),
        "hi": (np.stack(out["hi"]) if n
               else np.zeros((0, host["hi"].shape[1]), np.int32)),
    }
    return rows, needed

"""Jit'd wrappers for batched bounded search with implementation dispatch.

``impl``:
  * "bsearch" — branchless fixed-trip binary search (production path on
    CPU/host and the default inside the frontier engine),
  * "pallas"  — the TPU dense-count kernel (interpret mode on CPU),
  * "ref"     — the dense jnp oracle (tests).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import leapfrog, ref


@functools.partial(jax.jit, static_argnames=("strict",))
def _bsearch(col: jnp.ndarray, values: jnp.ndarray, lo: jnp.ndarray,
             hi: jnp.ndarray, strict: bool = True) -> jnp.ndarray:
    """Vectorized bounded binary search; log2(N)+1 fixed iterations."""
    n = col.shape[0]
    if n == 0:
        return lo
    trips = max(1, int(math.ceil(math.log2(n + 1))) + 1)
    dtype = lo.dtype

    def body(_, lh):
        lo_, hi_ = lh
        go = lo_ < hi_
        mid = (lo_ + hi_) >> 1
        x = col[jnp.clip(mid, 0, n - 1)]
        pred = (x < values) if strict else (x <= values)
        lo2 = jnp.where(go & pred, mid + 1, lo_)
        hi2 = jnp.where(go & ~pred, mid, hi_)
        return lo2, hi2

    lo_, _ = jax.lax.fori_loop(0, trips, body, (lo.astype(dtype),
                                                hi.astype(dtype)))
    return lo_


def lower_bound(col, values, lo, hi, impl: str = "bsearch"):
    if impl == "bsearch":
        return _bsearch(col, values, lo, hi, strict=True)
    if impl == "pallas":
        return leapfrog.lower_bound_pallas(col, values, lo, hi)
    if impl == "ref":
        return ref.lower_bound_ref(col, values, lo, hi)
    raise ValueError(impl)


def upper_bound(col, values, lo, hi, impl: str = "bsearch"):
    if impl == "bsearch":
        return _bsearch(col, values, lo, hi, strict=False)
    if impl == "pallas":
        return leapfrog.upper_bound_pallas(col, values, lo, hi)
    if impl == "ref":
        return ref.upper_bound_ref(col, values, lo, hi)
    raise ValueError(impl)

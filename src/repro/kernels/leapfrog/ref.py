"""Pure-jnp oracle for the batched leapfrog-seek (bounded searchsorted).

``lower_bound(col, v, lo, hi)`` = the least index p in [lo, hi] such that all
elements of col[lo:p] are < v (i.e. the insertion point of v restricted to the
window).  The oracle computes it by dense masked counting — O(M·N), obviously
correct, used to validate both the production binary search and the Pallas
kernel.
"""
from __future__ import annotations

import jax.numpy as jnp


def lower_bound_ref(col: jnp.ndarray, values: jnp.ndarray,
                    lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    n = col.shape[0]
    pos = jnp.arange(n, dtype=lo.dtype)[None, :]
    mask = (pos >= lo[:, None]) & (pos < hi[:, None]) & \
        (col[None, :] < values[:, None])
    return lo + jnp.sum(mask.astype(lo.dtype), axis=1)


def upper_bound_ref(col: jnp.ndarray, values: jnp.ndarray,
                    lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    n = col.shape[0]
    pos = jnp.arange(n, dtype=lo.dtype)[None, :]
    mask = (pos >= lo[:, None]) & (pos < hi[:, None]) & \
        (col[None, :] <= values[:, None])
    return lo + jnp.sum(mask.astype(lo.dtype), axis=1)

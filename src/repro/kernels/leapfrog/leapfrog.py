"""Pallas TPU kernel: batched bounded lower/upper bound ("leapfrog seek").

TPU adaptation (see DESIGN.md §2): the scalar galloping search of LFTJ maps
poorly onto the VPU — per-lane dynamic gathers from a large HBM-resident
array are the exact anti-pattern.  Instead each (query-block × column-block)
grid cell does a *dense masked comparison count*: for query q with window
[lo_q, hi_q), the bounded insertion index is

    lower_bound(q) = lo_q + |{ p : lo_q <= p < hi_q  and  col[p] < v_q }|

which is an (BQ × BC) broadcast compare + row reduction — pure VPU work on
VMEM tiles, accumulated across column blocks by the sequential TPU grid.
Block sizes keep the working set (BQ·BC comparisons) inside VMEM and the
lanes (last dim = BC) a multiple of 128.

For fixed relation size N this is O(N) per query versus O(log N) for the
scalar search; the crossover in the engine's regime (many thousand queries
per expansion against relation columns) favours the dense form on TPU, and
the column blocks stream at HBM bandwidth.  The host/CPU path of the engine
uses the branchless binary search in ``ops.py`` instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 512     # queries per block
DEFAULT_BC = 1024    # column elements per block (multiple of 128)


def _bound_kernel(v_ref, lo_ref, hi_ref, col_ref, out_ref, *,
                  n_valid: int, block_c: int, strict: bool):
    j = pl.program_id(1)
    base = j * block_c
    v = v_ref[...]          # (BQ,)
    lo = lo_ref[...]
    hi = hi_ref[...]
    col = col_ref[...]      # (BC,)
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (v.shape[0], block_c), 1)
    cmp = (col[None, :] < v[:, None]) if strict else (col[None, :] <= v[:, None])
    mask = cmp & (pos >= lo[:, None]) & (pos < hi[:, None]) & (pos < n_valid)
    # pin the accumulator dtype: under enable_x64 jnp.sum would promote
    # int32 to int64 and the store into the int32 out_ref would fail
    partial = jnp.sum(mask.astype(jnp.int32), axis=1, dtype=jnp.int32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = lo

    out_ref[...] += partial


def _bound_pallas(col: jnp.ndarray, values: jnp.ndarray,
                  lo: jnp.ndarray, hi: jnp.ndarray, *, strict: bool,
                  block_q: int = DEFAULT_BQ, block_c: int = DEFAULT_BC,
                  interpret: bool = True) -> jnp.ndarray:
    m = values.shape[0]
    n = col.shape[0]
    if n == 0:
        return lo
    grid = (pl.cdiv(m, block_q), pl.cdiv(n, block_c))
    kernel = functools.partial(_bound_kernel, n_valid=n, block_c=block_c,
                               strict=strict)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i, j: (i,)),   # values
            pl.BlockSpec((block_q,), lambda i, j: (i,)),   # lo
            pl.BlockSpec((block_q,), lambda i, j: (i,)),   # hi
            pl.BlockSpec((block_c,), lambda i, j: (j,)),   # column block
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), lo.dtype),
        interpret=interpret,
    )(values.astype(col.dtype), lo.astype(jnp.int32), hi.astype(jnp.int32),
      col)
    return out


def lower_bound_pallas(col, values, lo, hi, **kw):
    return _bound_pallas(col, values, lo, hi, strict=True, **kw)


def upper_bound_pallas(col, values, lo, hi, **kw):
    return _bound_pallas(col, values, lo, hi, strict=False, **kw)

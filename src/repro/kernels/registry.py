"""Kernel registry: the single entry-point convention for device kernels.

Every compute kernel in the repo is reached through this module, never by
importing an implementation module directly:

  * **bounded search** (``lower_bound``/``upper_bound``) — the batched
    leapfrog-seek primitive, with ``impl`` dispatch between the branchless
    fixed-trip binary search (production on CPU/host), the Pallas dense
    count kernel (``kernels/leapfrog``; interpret mode on CPU), and the
    dense jnp oracle (``kernels/leapfrog/ref.py`` — tests).  Folded here
    from the former ``kernels/leapfrog/ops.py``.
  * **fused EXPAND** (``expand_fn``) — one frontier-expansion step
    (DESIGN.md §2.7).  Two implementations: ``"pallas"`` — the fused
    single-pass kernel (``kernels/expand/fused.py``: guard-run
    enumeration, membership binary searches, mask reduction, and frontier
    compaction in one ``pallas_call``; interpret mode on CPU) — and
    ``"xla"`` — the original jnp op chain (``kernels/expand/xla.py``),
    the always-available fallback.  ``kernels/expand/ref.py`` is the
    plain-numpy oracle both are validated against.

Dispatch (``select_expand``): a forced mode wins (falling back to XLA only
if the Pallas build itself raises — recorded in ``failures()``); degenerate
specs (empty guard trie / empty participating relation, where expansion is
statically empty) always take the XLA path; otherwise ``"auto"`` resolves
per :class:`ExpandSpec` — on TPU/GPU the fused kernel is measured against
the XLA chain once per (spec, platform) and the winner is cached (the tiny
measured-autotune cache, :func:`autotune_cache`); on CPU ``"auto"`` picks
XLA without measuring (interpret mode exists for conformance, not speed —
measuring it would only burn test time; pass ``measure=True`` to force a
measurement anywhere).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .leapfrog import leapfrog, ref as leapfrog_ref

__all__ = ["ExpandSpec", "lower_bound", "upper_bound", "expand_fn",
           "select_expand", "autotune_cache", "failures",
           "clear_autotune_cache", "device_op_count",
           "save_autotune_cache", "load_autotune_cache",
           "autotune_entries", "merge_autotune_entries",
           "AUTOTUNE_CACHE_ENV"]


# ---------------------------------------------------------------------------
# Bounded search (the former kernels/leapfrog/ops.py)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("strict",))
def _bsearch(col: jnp.ndarray, values: jnp.ndarray, lo: jnp.ndarray,
             hi: jnp.ndarray, strict: bool = True) -> jnp.ndarray:
    """Vectorized bounded binary search; log2(N)+1 fixed iterations."""
    n = col.shape[0]
    if n == 0:
        return lo
    trips = max(1, int(math.ceil(math.log2(n + 1))) + 1)
    dtype = lo.dtype

    def body(_, lh):
        lo_, hi_ = lh
        go = lo_ < hi_
        mid = (lo_ + hi_) >> 1
        x = col[jnp.clip(mid, 0, n - 1)]
        pred = (x < values) if strict else (x <= values)
        lo2 = jnp.where(go & pred, mid + 1, lo_)
        hi2 = jnp.where(go & ~pred, mid, hi_)
        return lo2, hi2

    lo_, _ = jax.lax.fori_loop(0, trips, body, (lo.astype(dtype),
                                                hi.astype(dtype)))
    return lo_


def lower_bound(col, values, lo, hi, impl: str = "bsearch"):
    if impl == "bsearch":
        return _bsearch(col, values, lo, hi, strict=True)
    if impl == "pallas":
        return leapfrog.lower_bound_pallas(col, values, lo, hi)
    if impl == "ref":
        return leapfrog_ref.lower_bound_ref(col, values, lo, hi)
    raise ValueError(impl)


def upper_bound(col, values, lo, hi, impl: str = "bsearch"):
    if impl == "bsearch":
        return _bsearch(col, values, lo, hi, strict=False)
    if impl == "pallas":
        return leapfrog.upper_bound_pallas(col, values, lo, hi)
    if impl == "ref":
        return leapfrog_ref.upper_bound_ref(col, values, lo, hi)
    raise ValueError(impl)


# ---------------------------------------------------------------------------
# EXPAND dispatch + autotune
# ---------------------------------------------------------------------------

EXPAND_MODES = ("auto", "pallas", "xla")


@dataclass(frozen=True)
class ExpandSpec:
    """The dispatch key of one EXPAND(d) op: what the kernel choice may
    legitimately depend on.  Everything else (the actual trie arrays, the
    depth, the guard index) parameterizes the *built* function, not the
    *selection*."""

    capacity: int     # chunk capacity C
    n_vars: int       # assignment columns (order length)
    n_atoms: int      # lo/hi columns (atom count m)
    n_others: int     # participating membership atoms at this depth
    dtype: str        # trie column dtype (e.g. "int32")
    x64: bool         # 64-bit factor arithmetic enabled


# (spec, platform) -> chosen impl; (spec, platform) -> error string
_AUTOTUNE: Dict[Tuple[ExpandSpec, str], str] = {}
_FAILURES: Dict[Tuple[ExpandSpec, str], str] = {}

# measured-autotune persistence (ROADMAP follow-on from the kernel PR):
# autotuning costs one compile+timing of BOTH paths per (spec, platform);
# the sidecar makes that a once-per-machine cost instead of once-per-
# process.  Set REPRO_AUTOTUNE_CACHE to a JSON path to auto-load it before
# the first "auto" resolution and write through after every measurement.
# Only MEASURED decisions persist (``_MEASURED`` tracks them): the
# platform-heuristic defaults are free to recompute and persisting them
# would pre-empt a later ``measure=True`` run with a never-measured guess.
AUTOTUNE_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_SIDECAR_VERSION = 1
_sidecar_loaded = False
_MEASURED: set = set()  # keys whose _AUTOTUNE entry came from a timing run


def autotune_cache() -> Dict[Tuple[ExpandSpec, str], str]:
    return dict(_AUTOTUNE)


def failures() -> Dict[Tuple[ExpandSpec, str], str]:
    return dict(_FAILURES)


def clear_autotune_cache() -> None:
    global _sidecar_loaded
    _AUTOTUNE.clear()
    _FAILURES.clear()
    _MEASURED.clear()
    _sidecar_loaded = False


def autotune_entries() -> list:
    """The measured autotune decisions as JSON-able records — the sidecar
    file's ``entries`` list, exposed so larger snapshots (the serving
    layer's ``repro/serve/persist.py``) can embed the same records instead
    of shipping a second file format.  Heuristic (unmeasured) decisions
    are excluded, as in :func:`save_autotune_cache`."""
    return [{"spec": dataclasses.asdict(spec), "platform": platform,
             "choice": choice}
            for (spec, platform), choice in _AUTOTUNE.items()
            if (spec, platform) in _MEASURED]


def merge_autotune_entries(entries) -> int:
    """Merge sidecar-format records into the in-memory cache.

    In-memory decisions win (this process may have re-measured); malformed
    entries are skipped individually so one bad record cannot poison the
    rest.  Returns the number of entries merged."""
    if not isinstance(entries, (list, tuple)):
        return 0
    fields = {f.name for f in dataclasses.fields(ExpandSpec)}
    n = 0
    for ent in entries:
        try:
            spec_d = dict(ent["spec"])
            if set(spec_d) != fields:
                continue  # written by a different ExpandSpec revision
            key = (ExpandSpec(**spec_d), str(ent["platform"]))
            choice = str(ent["choice"])
            if choice not in ("pallas", "xla"):
                continue
        except (KeyError, TypeError, ValueError):
            continue
        if key not in _AUTOTUNE:
            _AUTOTUNE[key] = choice
            _MEASURED.add(key)  # sidecar entries originate from timing runs
            n += 1
    return n


def save_autotune_cache(path: Optional[str] = None) -> Optional[str]:
    """Persist the measured autotune decisions as a JSON sidecar.

    Entries are keyed by ``(spec, platform)``: each record carries the
    :class:`ExpandSpec` fields verbatim, so a process with a different
    capacity/arity mix shares only the entries that actually match.
    Heuristic (unmeasured) entries are not written — see the module
    comment.  On-disk entries are merged in first (in-memory wins), so
    sequential writers preserve each other's measurements; simultaneous
    writers are best-effort (no file lock — a lost entry just costs one
    re-measurement).  ``path`` defaults to ``$REPRO_AUTOTUNE_CACHE``;
    returns the path written, or ``None`` when there is neither a path
    nor anything to write (an empty save never clobbers an existing
    sidecar)."""
    path = path or os.environ.get(AUTOTUNE_CACHE_ENV)
    if not path:
        return None
    # merge the on-disk entries first (in-memory wins) so a write-through
    # doesn't simply replace what other processes measured.  Best-effort
    # only: the read-merge-replace is not atomic, so two processes
    # writing in the same instant can still lose one entry (it is a
    # cache — the loser re-measures once); no locking for that corner.
    if os.path.exists(path):
        load_autotune_cache(path)
    entries = autotune_entries()
    if not entries:
        return None
    payload = {"version": _SIDECAR_VERSION, "entries": entries}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)  # atomic: a concurrent reader never sees a torn file
    return path


def load_autotune_cache(path: Optional[str] = None) -> int:
    """Merge a JSON sidecar into the in-memory autotune cache.

    Returns the number of entries merged.  In-memory decisions win over
    the sidecar's (this process may have re-measured).  A missing,
    corrupt, or wrong-schema file is a *fallback to measuring*, never an
    error — exactly like a cold cache; malformed entries are skipped
    individually so one bad record cannot poison the rest."""
    path = path or os.environ.get(AUTOTUNE_CACHE_ENV)
    if not path:
        return 0
    try:
        with open(path) as f:
            payload = json.load(f)
        if payload.get("version") != _SIDECAR_VERSION:
            raise ValueError(
                f"sidecar version {payload.get('version')!r} != "
                f"{_SIDECAR_VERSION} (entry semantics may differ)")
        entries = payload["entries"]
        if not isinstance(entries, list):
            raise TypeError("entries must be a list")
    except (OSError, ValueError, KeyError, TypeError, AttributeError) as e:
        if os.path.exists(path):
            warnings.warn(f"ignoring unreadable autotune sidecar {path}: {e}")
        return 0
    return merge_autotune_entries(entries)


def _autoload_sidecar() -> None:
    """Load ``$REPRO_AUTOTUNE_CACHE`` once, lazily, before the first
    dispatch decision (import time would race with env setup in tests)."""
    global _sidecar_loaded
    if _sidecar_loaded:
        return
    _sidecar_loaded = True
    if os.environ.get(AUTOTUNE_CACHE_ENV):
        load_autotune_cache()


class _BenchChunk(NamedTuple):
    """Frontier-shaped chunk for autotune measurement (the kernel builders
    are generic over any assign/factor/valid/orig/lo/hi NamedTuple, so the
    registry does not need to import ``core.frontier``)."""

    assign: jnp.ndarray
    factor: jnp.ndarray
    valid: jnp.ndarray
    orig: jnp.ndarray
    lo: jnp.ndarray
    hi: jnp.ndarray


def _measure_chunk(spec: ExpandSpec, sizes: Sequence[int],
                   cap: int) -> _BenchChunk:
    """A synthetic chunk representative enough to time both paths: the
    first quarter of the rows valid, each spanning its atoms' full tries."""
    C, m, n = cap, spec.n_atoms, spec.n_vars
    n_valid = max(1, C // 4)
    factor_dtype = jnp.int64 if spec.x64 else jnp.int32
    return _BenchChunk(
        assign=jnp.zeros((C, n), jnp.int32),
        factor=jnp.ones((C,), factor_dtype),
        valid=jnp.asarray(np.arange(C) < n_valid),
        orig=jnp.zeros((C,), jnp.int32),
        lo=jnp.zeros((C, m), jnp.int32),
        hi=jnp.tile(jnp.asarray(list(sizes), jnp.int32)[None, :], (C, 1)))


def _time_fn(fn: Callable, F: _BenchChunk, reps: int = 2) -> float:
    jax.block_until_ready(fn(F))  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(F))
        best = min(best, time.perf_counter() - t0)
    return best


def select_expand(spec: ExpandSpec, mode: str = "auto",
                  platform: Optional[str] = None,
                  measure: Optional[bool] = None,
                  builders: Optional[Dict[str, Callable[[], Callable]]] = None,
                  sizes: Optional[Sequence[int]] = None) -> str:
    """Resolve ``mode`` to a concrete impl name for ``spec``.

    ``builders`` maps impl name to a zero-arg builder (needed only when a
    measurement actually runs); ``measure`` overrides the platform rule
    (None → measure on tpu/gpu only)."""
    if mode not in EXPAND_MODES:
        raise ValueError(f"expand_kernel must be one of {EXPAND_MODES}, "
                         f"got {mode!r}")
    platform = platform or jax.default_backend()
    if mode != "auto":
        return mode
    _autoload_sidecar()  # a persisted measurement beats re-measuring
    key = (spec, platform)
    if key in _AUTOTUNE:
        return _AUTOTUNE[key]
    do_measure = (platform in ("tpu", "gpu")) if measure is None else measure
    if not do_measure or builders is None:
        # CPU default: the XLA chain; interpret-mode Pallas is a
        # conformance vehicle, not a perf path
        # heuristic, not measured: cached in-process only (persisting it
        # would pre-empt a future measure=True run with a guess)
        choice = "pallas" if platform in ("tpu", "gpu") else "xla"
        _AUTOTUNE[key] = choice
        return choice
    cap = min(spec.capacity, 1 << 9)
    F = _measure_chunk(spec, sizes or [1] * spec.n_atoms, cap)
    timings: Dict[str, float] = {}
    for name in ("pallas", "xla"):
        try:
            timings[name] = _time_fn(builders[name](), F)
        except Exception as e:  # pragma: no cover - backend-specific
            _FAILURES[key] = f"{name}: {e}"
    choice = min(timings, key=timings.get) if timings else "xla"
    _AUTOTUNE[key] = choice
    _MEASURED.add(key)
    _maybe_writethrough()
    return choice


def _maybe_writethrough() -> None:
    """Persist after every new *measured* decision when the sidecar env
    var is set — the whole point is surviving the process."""
    if os.environ.get(AUTOTUNE_CACHE_ENV):
        try:
            save_autotune_cache()
        except OSError as e:  # pragma: no cover - fs-specific
            warnings.warn(f"could not persist autotune cache: {e}")


def expand_fn(spec: ExpandSpec, *, mode: str = "auto", impl: str = "bsearch",
              config=None, measure: Optional[bool] = None,
              d: int, g_ai: int, other_ais: Tuple[int, ...],
              g_col: jnp.ndarray, g_rs: jnp.ndarray,
              other_cols: Tuple[jnp.ndarray, ...], n_rows_g: int,
              sizes: Optional[Sequence[int]] = None,
              ) -> Tuple[Callable, str]:
    """Build the EXPAND(d) step for ``spec``: returns ``(fn, chosen)``
    where ``fn(F) -> (F', needed)`` and ``chosen`` names the impl that
    will actually run.  ``impl`` is the bounded-search flavor used by the
    XLA chain; ``config`` is a :class:`~.expand.fused.FusedExpandConfig`
    for the Pallas path."""
    from .expand import fused as _fused, xla as _xla  # lazy: no import cycle

    def build_xla():
        return _xla.build(d=d, g_ai=g_ai, other_ais=other_ais,
                          n_rows_g=n_rows_g, impl=impl,
                          g_col=g_col, g_rs=g_rs, other_cols=other_cols)

    def build_fused():
        return _fused.build(d=d, g_ai=g_ai, other_ais=other_ais,
                            n_rows_g=n_rows_g, g_col=g_col, g_rs=g_rs,
                            other_cols=other_cols, config=config)

    # statically-empty expansions (no guard runs, or an empty participating
    # relation makes every membership test fail): the XLA chain already
    # short-circuits these shapes — never worth a kernel launch
    degenerate = (n_rows_g == 0 or g_rs.shape[0] == 0
                  or any(c.shape[0] == 0 for c in other_cols))
    if degenerate:
        return build_xla(), "xla"
    chosen = select_expand(
        spec, mode=mode, measure=measure, sizes=sizes,
        builders={"pallas": build_fused, "xla": build_xla})
    if chosen == "pallas":
        try:
            fn = build_fused()
            # the builder only closes a jitted wrapper — the pallas_call
            # and its kernel are constructed at trace time, so validate
            # the trace eagerly (abstract, no compute) or a kernel bug
            # would only surface at the first call mid-query.  Backend
            # *compile* failures can still escape this (they are caught
            # by the autotune measurement on the "auto" path).
            jax.eval_shape(fn, _measure_chunk(spec, sizes or
                                              [1] * spec.n_atoms,
                                              spec.capacity))
            return fn, "pallas"
        except Exception as e:  # the always-available fallback
            _FAILURES[(spec, jax.default_backend())] = f"pallas: {e}"
            warnings.warn(f"fused EXPAND unavailable for {spec}: {e}; "
                          "falling back to the XLA path")
            return build_xla(), "xla"
    return build_xla(), "xla"


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

# primitives that are metadata/layout-only — XLA folds them into their
# producer/consumer, so they are not separately-materialized device ops
_METADATA_PRIMS = frozenset({
    "slice", "squeeze", "reshape", "broadcast_in_dim",
    "convert_element_type", "transpose", "copy"})
_CALL_PRIMS = ("pjit", "closed_call", "core_call", "remat", "custom_jvp_call",
               "custom_vjp_call", "custom_vjp_call_jaxpr")


def device_op_count(fn: Callable, *args) -> int:
    """Number of non-metadata primitive applications ``fn`` lowers to —
    the per-EXPAND "device op" figure in ``bench_expand_kernel``.  Call
    wrappers (pjit etc.) are descended into; a ``pallas_call`` counts as
    ONE op (its inner jaxpr is a single fused launch)."""

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in _CALL_PRIMS:
                sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                if sub is not None:
                    n += walk(getattr(sub, "jaxpr", sub))
                    continue
            if name in _METADATA_PRIMS:
                continue
            n += 1
        return n

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)

"""Checkpointing: atomic, retention-managed, mesh-agnostic, async-capable.

Arrays are gathered to host (fully replicated logical values) and written as
an ``.npz`` plus a JSON manifest under a temp name, then atomically renamed —
a crash mid-write never corrupts the latest checkpoint.  Because saved
values are logical (unsharded), a checkpoint can be restored under *any*
mesh (elastic re-scale: see runtime/elastic.py).  A background thread makes
saves non-blocking; ``wait()`` joins it (called before the next save and at
exit).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

from ..compat import tree_flatten_with_path

_SEP = "||"


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    flat, treedef = tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict] = None) -> None:
        self.wait()
        arrays, _ = _flatten(state)
        # pull to host before handing to the writer thread
        arrays = {k: np.asarray(v) for k, v in arrays.items()}
        meta = {"step": int(step), "extra": extra or {}}

        def write():
            tmp = os.path.join(self.dir, f".tmp-{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Any, Dict]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings — arrays are placed onto devices accordingly (this is
        what makes restore mesh-elastic)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        flat, treedef = tree_flatten_with_path(like)
        leaves = []
        shard_flat = jax.tree.leaves(shardings) if shardings is not None \
            else [None] * len(flat)
        for (pth, proto), shard in zip(flat, shard_flat):
            key = _SEP.join(_path_str(p) for p in pth)
            arr = data[key]
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return meta["step"], jax.tree.unflatten(treedef, leaves), meta["extra"]

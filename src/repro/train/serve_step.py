"""Serving steps: prefill and single-token decode, jit/AOT-lowerable.

``decode_*`` dry-run shapes lower exactly this serve_step: one new token
against a seq_len-deep cache (dense KV for attention archs, O(1) state for
recurrent archs — which is why only those run ``long_500k``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import Model


def make_prefill_step(model: Model):
    def prefill(params, batch):
        return model.prefill(params, batch)
    return prefill


def make_decode_step(model: Model):
    def decode(params, caches, tokens, pos):
        return model.decode(params, caches, tokens, pos)
    return decode


def greedy_generate(model: Model, params, batch: Dict, steps: int,
                    ) -> jnp.ndarray:
    """Host-driven greedy decoding (example/serving driver)."""
    from ..models.kvcache import pad_caches
    logits, caches = jax.jit(model.prefill)(params, batch)
    caches = pad_caches(model.cfg, caches, steps)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    T0 = batch["tokens"].shape[1]
    out = [tok]
    decode = jax.jit(model.decode)
    for i in range(steps - 1):
        logits, caches = decode(params, caches, tok[:, None],
                                jnp.asarray(T0 + i, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)

"""Training loop with checkpoint/restart, preemption handling and straggler
watch.  Single-process (all local devices); the multi-host variant changes
only the mesh construction and per-host data sharding (both injected).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.ckpt import CheckpointManager
from ..data import tokens as dtok
from ..models import Model
from ..optim.adamw import OptConfig
from ..runtime.fault import PreemptionGuard, StragglerWatch
from .train_step import TrainConfig, init_train_state, make_train_step


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    seed: int = 0


def train(model: Model, data_cfg: dtok.DataConfig, tcfg: TrainConfig,
          lcfg: LoopConfig, mesh=None,
          log: Callable[[str], None] = print,
          fail_at_step: Optional[int] = None) -> Dict[str, List[float]]:
    """Run (or resume) training.  ``fail_at_step`` injects a crash (tests).

    Returns the metric history.  Restart-safe: rerunning with the same
    ckpt_dir resumes from the latest checkpoint and reproduces the same
    data stream (the pipeline is a pure function of step).
    """
    ckpt = CheckpointManager(lcfg.ckpt_dir, keep=lcfg.keep)
    step_fn = jax.jit(make_train_step(model, tcfg, mesh))
    guard = PreemptionGuard().install()
    watch = StragglerWatch(on_flag=lambda s, m: log(
        f"[straggler] step took {s:.2f}s vs median {m:.2f}s"))

    start_step = 0
    if ckpt.latest_step() is not None:
        restored, state, extra = _restore(ckpt, model)
        start_step = restored
        log(f"[resume] restored checkpoint at step {start_step}")
    else:
        state = init_train_state(model, jax.random.PRNGKey(lcfg.seed))

    history: Dict[str, List[float]] = {"loss": [], "step_time": []}
    for step in range(start_step, lcfg.total_steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch_np = dtok.batch_at(data_cfg, step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        watch.observe(dt)
        history["loss"].append(loss)
        history["step_time"].append(dt)
        if (step + 1) % lcfg.log_every == 0:
            log(f"step {step + 1:5d}  loss {loss:.4f}  {dt * 1e3:.0f} ms")
        stop = guard.should_stop
        if (step + 1) % lcfg.ckpt_every == 0 or stop or \
                step + 1 == lcfg.total_steps:
            ckpt.save(step + 1, state)
        if stop:
            log("[preempt] stop requested; checkpoint written, exiting")
            break
    ckpt.wait()
    return history


def _restore(ckpt: CheckpointManager, model: Model):
    from ..runtime.elastic import restore_for_mesh
    return restore_for_mesh(ckpt, model, mesh=None)

"""Training step: loss + grad (+ microbatch accumulation, grad compression),
AdamW update.  Pure function of (state, batch) so it jits and AOT-lowers for
the production mesh.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import Model
from ..optim import adamw
from ..sharding import rules as shr


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    grad_dtype: str = "float32"   # "bfloat16" = compressed DP all-reduce
    opt: adamw.OptConfig = adamw.OptConfig()


def init_train_state(model: Model, key) -> Dict:
    params = model.init(key)
    return {"params": params, "opt": adamw.init_state(params)}


def make_train_step(model: Model, tcfg: TrainConfig,
                    mesh=None):
    """Returns step(state, batch) -> (state, metrics)."""
    gdt = jnp.bfloat16 if tcfg.grad_dtype == "bfloat16" else jnp.float32

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def step(state, batch):
        params = state["params"]
        if mesh is not None:
            batch = {k: shr.constrain_batch(v, mesh)
                     for k, v in batch.items()}
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def resh(x):
                y = x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
                if mesh is None:
                    return y
                # keep the per-microbatch batch dim fully data-sharded —
                # without this GSPMD splits the old batch sharding across
                # (mb, B/mb), silently quartering the effective DP degree
                from jax.sharding import NamedSharding, PartitionSpec as P
                spec = shr.batch_spec(mesh)
                full = P(*([None] + list(spec) +
                           [None] * (y.ndim - 2)))
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, full))

            mbatch = jax.tree.map(resh, batch)

            def acc_fn(carry, mb_batch):
                g_acc, l_acc = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb_batch)
                grads = jax.tree.map(lambda a: a.astype(gdt), grads)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((), jnp.float32)), mbatch)
            grads = jax.tree.map(lambda g: (g / mb).astype(gdt), grads)
            loss = loss_sum / mb
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda a: a.astype(gdt), grads)
        new_params, new_opt, opt_metrics = adamw.update(
            tcfg.opt, params, grads, state["opt"])
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def state_shardings(model: Model, mesh, state_shapes=None):
    """NamedShardings for the train state under the given mesh."""
    from ..models import specs as S
    logical = model.logical_axes()
    shapes = model.param_shapes()
    p_shard = jax.tree.map(
        lambda lg, sh: shr.named_sharding(mesh, lg, sh.shape),
        logical, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and (
            len(x) == 0 or isinstance(x[0], (str, type(None)))))
    return {"params": p_shard,
            "opt": {"m": p_shard, "v": p_shard,
                    "step": shr.named_sharding(mesh, (), ())}}

"""Shared benchmark plumbing: timing, budgets, CSV rows."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.db import Counters, JoinBudgetExceeded

# memory-access budget standing in for the paper's 10-hour timeout
DEFAULT_BUDGET = 25_000_000

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def run_ref(name: str, fn: Callable[[Counters], int],
            budget: int = DEFAULT_BUDGET) -> Optional[Dict]:
    """Time one reference-engine invocation with an access budget."""
    counters = Counters(budget=budget)
    t0 = time.perf_counter()
    try:
        result = fn(counters)
    except JoinBudgetExceeded:
        dt = time.perf_counter() - t0
        emit(name, dt * 1e6,
             f"TIMEOUT(budget={budget});mem={counters.mem_accesses}")
        return None
    dt = time.perf_counter() - t0
    snap = counters.snapshot()
    emit(name, dt * 1e6,
         f"count={result};mem={snap['mem_accesses']};"
         f"hits={snap['cache_hits']};intrmd={snap['intermediate_tuples']}")
    return {"result": result, "seconds": dt, **snap}


def run_jax(name: str, fn: Callable[[], int]) -> Dict:
    t0 = time.perf_counter()
    result = fn()
    dt = time.perf_counter() - t0
    emit(name, dt * 1e6, f"count={result}")
    return {"result": result, "seconds": dt}


def run_jax_cached(name: str, eng) -> Dict:
    """Time one JaxCachedTrieJoin.count() and emit its tier-2 stats."""
    t0 = time.perf_counter()
    result = eng.count()
    dt = time.perf_counter() - t0
    s = eng.stats
    hit_rate = s["tier2_hits"] / max(1, s["tier2_probes"])
    emit(name, dt * 1e6,
         f"count={result};hit_rate={hit_rate:.4f};hits={s['tier2_hits']};"
         f"probes={s['tier2_probes']};evict={s['tier2_evictions']};"
         f"slots={s['tier2_slots']};resizes={s['tier2_resizes']};"
         f"t1_collapsed={s['tier1_rows_collapsed']}")
    return {"result": result, "seconds": dt, "hit_rate": hit_rate, **s}

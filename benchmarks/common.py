"""Shared benchmark plumbing: timing, budgets, CSV rows + JSON records."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.db import Counters, JoinBudgetExceeded

# memory-access budget standing in for the paper's 10-hour timeout
DEFAULT_BUDGET = 25_000_000

ROWS: List[Tuple[str, float, str]] = []
# structured mirror of every emitted row, consumed by ``run.py --json``
RECORDS: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str,
         record: Optional[Dict] = None) -> None:
    ROWS.append((name, us_per_call, derived))
    rec = {"name": name, "us_per_call": us_per_call, "derived": derived}
    if record:
        rec.update(record)
    RECORDS.append(rec)
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def run_ref(name: str, fn: Callable[[Counters], int],
            budget: int = DEFAULT_BUDGET) -> Optional[Dict]:
    """Time one reference-engine invocation with an access budget."""
    counters = Counters(budget=budget)
    t0 = time.perf_counter()
    try:
        result = fn(counters)
    except JoinBudgetExceeded:
        dt = time.perf_counter() - t0
        emit(name, dt * 1e6,
             f"TIMEOUT(budget={budget});mem={counters.mem_accesses}",
             record={"kind": "ref", "timeout": True, "seconds": dt})
        return None
    dt = time.perf_counter() - t0
    snap = counters.snapshot()
    emit(name, dt * 1e6,
         f"count={result};mem={snap['mem_accesses']};"
         f"hits={snap['cache_hits']};intrmd={snap['intermediate_tuples']}",
         record={"kind": "ref", "result": result, "seconds": dt, **snap})
    return {"result": result, "seconds": dt, **snap}


def run_jax(name: str, fn: Callable[[], int]) -> Dict:
    t0 = time.perf_counter()
    result = fn()
    dt = time.perf_counter() - t0
    emit(name, dt * 1e6, f"count={result}",
         record={"kind": "jax", "result": result, "seconds": dt})
    return {"result": result, "seconds": dt}


def run_jax_cached(name: str, eng) -> Dict:
    """Time one JaxCachedTrieJoin.count() and emit its tier-2 stats."""
    t0 = time.perf_counter()
    result = eng.count()
    dt = time.perf_counter() - t0
    s = eng.stats
    hit_rate = s["tier2_hits"] / max(1, s["tier2_probes"])
    emit(name, dt * 1e6,
         f"count={result};hit_rate={hit_rate:.4f};hits={s['tier2_hits']};"
         f"probes={s['tier2_probes']};evict={s['tier2_evictions']};"
         f"slots={s['tier2_slots']};resizes={s['tier2_resizes']};"
         f"t1_collapsed={s['tier1_rows_collapsed']}",
         record={"kind": "jax-cached", "result": result, "seconds": dt,
                 "hit_rate": hit_rate, **s})
    return {"result": result, "seconds": dt, "hit_rate": hit_rate, **s}


def run_jax_eval(name: str, eng) -> Dict:
    """Time one full materialization pass of a (possibly warm) JAX engine
    and emit its tier-2 replay stats.  Calling this twice on the same
    engine measures the paper §3.4 recurring-subjoin claim: the second
    pass replays cached row blocks instead of recomputing.  Engine stats
    accumulate over the engine's lifetime, so counters are reported as
    *per-pass deltas* — a warm pass's hit rate is its own, not diluted by
    the cold pass (slab_rows stays absolute: it is a level, not a
    counter)."""
    s0 = dict(getattr(eng, "stats", {}) or {})
    t0 = time.perf_counter()
    n = sum(b.shape[0] for b in eng.evaluate())
    dt = time.perf_counter() - t0
    s1 = dict(getattr(eng, "stats", {}) or {})
    levels = ("tier2_slab_rows", "tier2_slots")
    s = {k: v - s0.get(k, 0) for k, v in s1.items()
         if isinstance(v, int) and k not in levels}
    s.update({k: s1[k] for k in levels if k in s1})
    hit_rate = s.get("tier2_hits", 0) / max(1, s.get("tier2_probes", 0))
    emit(name, dt * 1e6,
         f"count={n};hit_rate={hit_rate:.4f};"
         f"replay_hits={s.get('tier2_replay_hits', 0)};"
         f"slab_rows={s.get('tier2_slab_rows', 0)};"
         f"flushes={s.get('tier2_payload_flushes', 0)}",
         record={"kind": "jax-eval", "result": n, "seconds": dt,
                 "hit_rate": hit_rate, **s})
    return {"result": n, "seconds": dt, **s}


def run_engine_result(name: str, fn: Callable[[], "object"]) -> Dict:
    """Run an ``engine.count``/``engine.evaluate`` facade call and emit its
    plan/compile/exec wall-time split (satellite: jit warm-up is no longer
    charged to the algorithm) plus any tier-2 counters — including the
    evaluation-mode replay stats (hits served from the row-block slab)."""
    res = fn()
    s = res.counters
    hit_rate = (s.get("tier2_hits", 0) / max(1, s.get("tier2_probes", 0))
                if s else 0.0)
    replay_hits = s.get("tier2_replay_hits", 0) if s else 0
    replay_rate = (replay_hits / max(1, s.get("tier2_probes", 0))
                   if s else 0.0)
    emit(name, res.exec_s * 1e6,
         f"count={res.count};plan_s={res.plan_s:.4f};"
         f"compile_s={res.compile_s:.4f};exec_s={res.exec_s:.4f};"
         f"hit_rate={hit_rate:.4f};replay_hits={replay_hits};"
         f"slab_rows={s.get('tier2_slab_rows', 0) if s else 0}",
         record={"kind": "engine", "result": res.count,
                 "seconds": res.wall_s, "plan_s": res.plan_s,
                 "compile_s": res.compile_s, "exec_s": res.exec_s,
                 "hit_rate": hit_rate, "replay_rate": replay_rate,
                 "algorithm": res.algorithm,
                 "backend": res.backend, **(s or {})})
    return {"result": res.count, "seconds": res.wall_s,
            "exec_s": res.exec_s, "hit_rate": hit_rate,
            "replay_hits": replay_hits}

"""Query-serving latency under a Zipf-mixed workload (DESIGN.md §2.9).

Three regimes over the SAME workload — a stream of isomorphic variants of
a few recurring query shapes, shape frequency Zipf-distributed the way a
production query log is:

* ``serve/cold``            — ``max_plans=0``: every query pays planning +
  engine construction + jit compile (the one-shot facade's regime).
* ``serve/plan-warm``       — the plan cache resident after one warm-up
  pass: isomorphic queries share compiled engines, tier-2 tables
  compound across queries.
* ``serve/persistent-warm`` — a FRESH server whose state was loaded from
  a snapshot written by the plan-warm server: its very first queries hit
  both the plan cache and the persisted payload slabs
  (``tier2_replay_hits > 0`` with zero process-local warm-up).

Each regime's record carries p50/p99 latency and throughput; the derived
column pins the headline claim — plan-cache-warm p50 beats cold p50 —
plus the persistent regime's replay-hit count (must be nonzero: warm
state genuinely crossed the process/snapshot boundary).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.configs.paper_clftj import TPU_SERVE
from repro.core import cycle_query, path_query
from repro.core.cq import CQ
from repro.core.db import graph_db
from repro.serve import JoinServer

from .common import emit

import dataclasses

CFG = dataclasses.replace(TPU_SERVE, cache_slots=1 << 10, cache_assoc=4,
                          payload_rows=1 << 14, frontier_capacity=1 << 14)
SHAPES = [path_query(3), cycle_query(3), path_query(4)]
N_QUERIES = 16
N_COLD = 6           # cold pays a full compile per query — keep it short


def _db():
    from repro.data.graphs import zipf_graph
    return graph_db(zipf_graph(24, 360, 1.1, seed=11))


def _scramble(q: CQ, seed: int) -> CQ:
    from repro.serve.canonical import rename_query
    rng = np.random.default_rng(seed)
    variables = list(q.variables)
    names = [f"s{i}" for i in rng.permutation(len(variables))]
    atoms = list(rename_query(q, dict(zip(variables, names))).atoms)
    rng.shuffle(atoms)
    return CQ(tuple(atoms))


def _workload(n: int, seed: int):
    """Zipf-mixed shape choice, every instance an isomorphic variant."""
    rng = np.random.default_rng(seed)
    return [_scramble(SHAPES[min(int(rng.zipf(1.6)) - 1, len(SHAPES) - 1)],
                      seed * 977 + i)
            for i in range(n)]


def _measure(srv: JoinServer, work):
    lat, replay, hits = [], 0, 0
    t_all = time.perf_counter()
    for q in work:
        t0 = time.perf_counter()
        r = srv.evaluate(q)
        lat.append(time.perf_counter() - t0)
        replay += r.tier2_replay_hits
        hits += int(r.plan_cache_hit)
    span = time.perf_counter() - t_all
    lat_ms = np.array(lat) * 1e3
    return {"p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "mean_ms": float(lat_ms.mean()),
            "qps": len(work) / span,
            "queries": len(work),
            "plan_hits": hits,
            "replay_hits": replay}


def main() -> None:
    db = _db()
    work = _workload(N_QUERIES, seed=5)

    with JoinServer(db, CFG, max_plans=0) as srv:      # always-cold regime
        cold = _measure(srv, work[:N_COLD])
    emit("serve/cold", cold["p50_ms"] * 1e3,
         f"p50_ms={cold['p50_ms']:.1f};p99_ms={cold['p99_ms']:.1f};"
         f"qps={cold['qps']:.2f}",
         record={"kind": "serve", "regime": "cold", **cold})

    with JoinServer(db, CFG, max_plans=16) as srv:
        _measure(srv, work)                            # warm-up pass
        warm = _measure(srv, work)
        snap = os.path.join(tempfile.mkdtemp(prefix="bench_serve_"),
                            "snap.npz")
        t0 = time.perf_counter()
        srv.save_snapshot(snap)
        save_s = time.perf_counter() - t0
    speedup = cold["p50_ms"] / max(warm["p50_ms"], 1e-9)
    emit("serve/plan-warm", warm["p50_ms"] * 1e3,
         f"p50_ms={warm['p50_ms']:.1f};p99_ms={warm['p99_ms']:.1f};"
         f"qps={warm['qps']:.2f};p50_speedup_vs_cold={speedup:.1f}x;"
         f"p50_improves={warm['p50_ms'] < cold['p50_ms']}",
         record={"kind": "serve", "regime": "plan-warm",
                 "p50_speedup_vs_cold": speedup,
                 "p50_improves_over_cold":
                     bool(warm["p50_ms"] < cold["p50_ms"]), **warm})

    with JoinServer(db, CFG, max_plans=16) as srv:     # fresh "process"
        t0 = time.perf_counter()
        summary = srv.load_snapshot(snap)
        load_s = time.perf_counter() - t0
        pers = _measure(srv, work)                     # FIRST pass, no warm-up
    os.remove(snap)
    emit("serve/persistent-warm", pers["p50_ms"] * 1e3,
         f"p50_ms={pers['p50_ms']:.1f};p99_ms={pers['p99_ms']:.1f};"
         f"qps={pers['qps']:.2f};replay_hits={pers['replay_hits']};"
         f"loaded_plans={summary['plans']};load_s={load_s:.2f}",
         record={"kind": "serve", "regime": "persistent-warm",
                 "snapshot_save_s": save_s, "snapshot_load_s": load_s,
                 "loaded_plans": summary["plans"],
                 "loaded_tables": summary["tables"], **pers})


if __name__ == "__main__":
    main()

"""LM substrate micro-bench: wall-clock train/decode step on CPU for three
reduced arch families (the full-scale numbers live in the dry-run roofline
tables, EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import Model
from repro.train.train_step import TrainConfig, init_train_state, \
    make_train_step

from .common import emit


def main() -> None:
    for name in ("minitron-8b", "qwen3-moe-235b-a22b", "rwkv6-7b"):
        cfg = get_arch(name + "-smoke")
        model = Model(cfg)
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, TrainConfig()))
        batch = {"tokens": jnp.ones((4, 32), jnp.int32),
                 "targets": jnp.ones((4, 32), jnp.int32)}
        state, _ = step(state, batch)      # compile
        t0 = time.perf_counter()
        iters = 5
        for _ in range(iters):
            state, metrics = step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = (time.perf_counter() - t0) / iters
        emit(f"lm/{name}-smoke/train_step", dt * 1e6,
             f"loss={float(metrics['loss']):.3f}")


if __name__ == "__main__":
    main()

"""Paper Fig 10: dynamic cache size.  CLFTJ count under bounded caches —
speedup grows with capacity; even small caches deliver most of it.

Three sweeps:

* ``ref``: the host reference engine over capacity bounds (the paper's
  figure as-is).
* ``jax``: the vectorized engine over tier-2 policy × slot count on the
  skewed-TD workload (bench_td_skew's zigzag cycle keyed on the Zipf
  person attribute), reporting the per-policy hit rate — the signal the
  dynamic sizing controller consumes.  At equal slots, set-associative
  LRU should meet or beat direct-mapped (conflict misses on hot keys).
* ``slab``: the *evaluation-mode* memory knob (DESIGN.md §2.6): replay
  hit rate vs payload arena rows on the same workload — the paper's cache
  size ↔ recomputation trade-off measured on materialization, where a
  too-small arena shows up as epoch flushes, not wrong answers.
"""
from __future__ import annotations

from repro.core import (CacheConfig, CachePolicy, choose_plan, clftj_count,
                        lftj_count, two_relation_cycle_query, cycle_query)
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.data.graphs import dataset

from .bench_td_skew import TDS, skewed_db, zigzag_cycle
from .common import run_jax_cached, run_ref

CAPS = (0, 1_000, 10_000, 100_000, None)  # None = unbounded

JAX_SLOTS = (256, 1024, 4096)
JAX_POLICIES = (
    ("direct", lambda s: CacheConfig(policy="direct", slots=s)),
    ("assoc4", lambda s: CacheConfig(policy="setassoc", slots=s, assoc=4)),
    ("cost4", lambda s: CacheConfig(policy="costaware", slots=s, assoc=4)),
    ("adaptive", lambda s: CacheConfig(
        policy="setassoc", slots=max(64, s // 4), assoc=4, dynamic=True,
        budget=s, min_slots=64, resize_interval=4)),
)


def ref_size_sweep() -> None:
    imdb = dataset("imdb-like")
    wiki = dataset("wiki-vote-like")
    cases = [
        ("imdb/4-cycle", imdb,
         two_relation_cycle_query(4, ["male_cast", "female_cast"])),
        ("imdb/6-cycle", imdb,
         two_relation_cycle_query(6, ["male_cast", "female_cast"])),
        ("wiki-vote/6-cycle", wiki, cycle_query(6)),
    ]
    for cname, db, q in cases:
        td, order = choose_plan(q, db.stats())
        run_ref(f"fig10/{cname}/lftj",
                lambda c: lftj_count(q, order, db, c))
        for cap in CAPS:
            pol = CachePolicy(capacity=cap) if cap is not None \
                else CachePolicy()
            label = "inf" if cap is None else str(cap)
            run_ref(f"fig10/{cname}/clftj-cap{label}",
                    lambda c: clftj_count(q, td, order, db, pol, c))


def jax_policy_sweep(n: int = 4, capacity: int = 1 << 11) -> dict:
    """Policy × slots hit-rate table on the skewed-TD workload; returns
    {(policy, slots): hit_rate} for programmatic checks."""
    db = skewed_db()
    q = zigzag_cycle(n)
    td = TDS[n]["TD1-person"]       # caches keyed on the skewed attribute
    td.validate(q)
    order = td.strongly_compatible_order()
    rates = {}
    for slots in JAX_SLOTS:
        for pname, mk in JAX_POLICIES:
            eng = JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                                    cache=mk(slots))
            rec = run_jax_cached(f"fig10jax/{n}-zigzag/{pname}-s{slots}", eng)
            rates[(pname, slots)] = rec["hit_rate"]
    return rates


SLAB_ROWS = (1 << 10, 1 << 13, 1 << 16)


def slab_budget_sweep(n: int = 4, capacity: int = 1 << 11) -> dict:
    """Evaluation-mode replay hit rate vs payload arena size on the skewed
    zigzag — the paper's size↔recomputation trade-off on materialization:
    a small arena epoch-flushes and re-stores (low warm hit rate), a large
    one replays nearly every recurring bag.  Cold + warm pass per size;
    returns {payload_rows: warm record}."""
    from repro.core.cached_frontier import JaxCachedTrieJoin
    from .bench_eval_queries import small_skewed_db
    from .common import run_jax_eval
    db = small_skewed_db()
    q = zigzag_cycle(n)
    td = TDS[n]["TD1-person"]
    td.validate(q)
    order = td.strongly_compatible_order()
    out = {}
    for rows in SLAB_ROWS:
        cache = CacheConfig(policy="setassoc", slots=1 << 14, assoc=8,
                            cache_payloads=True, payload_rows=rows)
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                                cache=cache)
        run_jax_eval(f"fig10slab/{n}-zigzag/payload-r{rows}-cold", eng)
        out[rows] = run_jax_eval(
            f"fig10slab/{n}-zigzag/payload-r{rows}-warm", eng)
    return out


def main() -> None:
    ref_size_sweep()
    jax_policy_sweep()
    slab_budget_sweep()


if __name__ == "__main__":
    main()

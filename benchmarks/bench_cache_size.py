"""Paper Fig 10: dynamic cache size.  CLFTJ count under bounded caches —
speedup grows with capacity; even small caches deliver most of it."""
from __future__ import annotations

from repro.core import (CachePolicy, choose_plan, clftj_count, lftj_count,
                        two_relation_cycle_query, cycle_query)
from repro.data.graphs import dataset

from .common import run_ref

CAPS = (0, 1_000, 10_000, 100_000, None)  # None = unbounded


def main() -> None:
    imdb = dataset("imdb-like")
    wiki = dataset("wiki-vote-like")
    cases = [
        ("imdb/4-cycle", imdb,
         two_relation_cycle_query(4, ["male_cast", "female_cast"])),
        ("imdb/6-cycle", imdb,
         two_relation_cycle_query(6, ["male_cast", "female_cast"])),
        ("wiki-vote/6-cycle", wiki, cycle_query(6)),
    ]
    for cname, db, q in cases:
        td, order = choose_plan(q, db.stats())
        run_ref(f"fig10/{cname}/lftj",
                lambda c: lftj_count(q, order, db, c))
        for cap in CAPS:
            pol = CachePolicy(capacity=cap) if cap is not None \
                else CachePolicy()
            label = "inf" if cap is None else str(cap)
            run_ref(f"fig10/{cname}/clftj-cap{label}",
                    lambda c: clftj_count(q, td, order, db, pol, c))


if __name__ == "__main__":
    main()

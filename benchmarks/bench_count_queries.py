"""Paper Fig 5: count-query runtimes (5-path, 5-cycle, 5-rand) across
datasets, for LFTJ / CLFTJ / YTD — plus the §1 memory-access analysis
(derived column carries the access counts)."""
from __future__ import annotations

from repro.core import (CachePolicy, choose_plan, clftj_count, lftj_count,
                        ytd_count, path_query, cycle_query,
                        random_graph_query, jax_clftj_count)
from repro.data.graphs import dataset

from .common import run_ref, run_jax

DATASETS = ("wiki-vote-like", "gnutella-like", "ca-grqc-like")
QUERIES = (("5-path", lambda: path_query(5)),
           ("5-cycle", lambda: cycle_query(5)),
           ("5-rand(0.4)", lambda: random_graph_query(5, 0.4, seed=1)))


def main() -> None:
    for ds in DATASETS:
        db = dataset(ds)
        for qname, qf in QUERIES:
            q = qf()
            td, order = choose_plan(q, db.stats())
            run_ref(f"fig5/{ds}/{qname}/lftj",
                    lambda c: lftj_count(q, order, db, c))
            run_ref(f"fig5/{ds}/{qname}/clftj",
                    lambda c: clftj_count(q, td, order, db, None, c))
            run_ref(f"fig5/{ds}/{qname}/ytd",
                    lambda c: ytd_count(q, td, db, c))
            run_jax(f"fig5/{ds}/{qname}/clftj-jax",
                    lambda: jax_clftj_count(q, td, order, db,
                                            capacity=1 << 15))


if __name__ == "__main__":
    main()

"""Streaming async EMIT vs one-shot drain (DESIGN.md §2.8).

Three sections, all on small recurring-bag workloads so the module doubles
as the CI bench-smoke config (``scripts/verify.sh --bench-smoke`` runs
exactly this module and schema-checks the emitted JSON):

* ``stream/host`` — host-executor evaluation of the bowtie + 4-zigzag
  queries, one-shot ``evaluate()`` vs ``evaluate_stream()`` (warm jit,
  payload cache on): wall time, block count, and the async-queue
  high-water mark.  On CPU the two are expected to be close — the number
  that transfers to an accelerator is the overlap structure (copies
  issued per block instead of one pass-end drain), which the record pins
  via ``async_issues``/``blocking_syncs``.
* ``stream/static`` — trace-time ``StaticCLFTJ.evaluate_static`` cold
  then warm (tables round-tripped): the warm pass must report
  ``tier2_replay_hits > 0`` (payload splice in the static executor).
* ``stream/facade`` — ``engine.evaluate_stream`` end-to-end with the
  Result totals check riding in the derived column.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CacheConfig, SyncCounter, bowtie_query, choose_plan
from repro.core import engine
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.core.cq import cycle_query
from repro.core.db import graph_db
from repro.core.distributed import StaticCLFTJ

from .common import emit


def _zipf_db(nv=30, ne=300, a=1.1, seed=47):
    from repro.data.graphs import zipf_graph
    return graph_db(zipf_graph(nv, ne, a, seed=seed))


_PAY = CacheConfig(policy="setassoc", slots=256, assoc=4,
                   cache_payloads=True, payload_rows=1 << 14)


def host_stream_section(db) -> None:
    for qname, q in [("bowtie", bowtie_query()), ("zigzag4", cycle_query(4))]:
        td, order = choose_plan(q, db.stats())
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 10,
                                cache=_PAY)
        n_warm = sum(b.shape[0] for b in eng.evaluate())  # jit warm-up pass
        t0 = time.perf_counter()
        n_one = sum(b.shape[0] for b in eng.evaluate())
        dt_one = time.perf_counter() - t0
        with SyncCounter() as sc:
            t0 = time.perf_counter()
            blocks = list(eng.evaluate_stream())
            dt_st = time.perf_counter() - t0
        n_st = sum(b.shape[0] for b in blocks)
        ex = eng.last_executor
        qx = ex.emit_queue
        assert n_st == n_one == n_warm, (n_st, n_one, n_warm)
        emit(f"stream/host/{qname}", dt_st * 1e6,
             f"count={n_st};blocks={len(blocks)};one_shot_s={dt_one:.4f};"
             f"async_issues={sc.async_count};blocking_syncs={sc.count};"
             f"high_water={qx.high_water}",
             record={"kind": "stream-host", "result": n_st,
                     "seconds": dt_st, "one_shot_seconds": dt_one,
                     "blocks": len(blocks),
                     "emitted_blocks": ex.emitted_blocks,
                     "queue_high_water": qx.high_water,
                     "queue_issued": qx.issued,
                     "async_issues": sc.async_count,
                     "blocking_syncs": sc.count,
                     "replay_hits": eng.stats["tier2_replay_hits"]})


def static_stream_section(db) -> None:
    q = bowtie_query()
    td, order = choose_plan(q, db.stats())
    eng = StaticCLFTJ(q, td, order, db, capacity=1 << 14, cache=_PAY)
    t0 = time.perf_counter()
    rows, stats, tables = eng.evaluate_static()
    dt_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows2, stats2, _ = eng.evaluate_static(tables)
    dt_warm = time.perf_counter() - t0
    assert rows.shape == rows2.shape, (rows.shape, rows2.shape)
    assert not stats["overflow"] and not stats2["overflow"], (stats, stats2)
    assert stats2["count"] == stats["count"], (stats, stats2)
    emit("stream/static/bowtie", dt_warm * 1e6,
         f"count={stats2['count']};replay_hits={stats2['tier2_replay_hits']};"
         f"cold_s={dt_cold:.4f}",
         record={"kind": "stream-static", "result": stats2["count"],
                 "seconds": dt_warm, "cold_seconds": dt_cold,
                 "replay_hits": stats2["tier2_replay_hits"],
                 "overflow": stats2["overflow"]})


def facade_section(db) -> None:
    q = cycle_query(4)
    rs = engine.evaluate_stream(q, db, capacity=1 << 10, cache=_PAY)
    n = sum(b.shape[0] for b in rs)
    res = rs.result
    ok = res is not None and res.count == n
    emit("stream/facade/zigzag4", res.exec_s * 1e6,
         f"count={n};totals_ok={ok};plan_s={res.plan_s:.4f};"
         f"compile_s={res.compile_s:.4f};exec_s={res.exec_s:.4f}",
         record={"kind": "stream-facade", "result": n, "totals_ok": ok,
                 "seconds": res.wall_s, "plan_s": res.plan_s,
                 "compile_s": res.compile_s, "exec_s": res.exec_s})


def main() -> None:
    db = _zipf_db()
    host_stream_section(db)
    static_stream_section(db)
    facade_section(db)


if __name__ == "__main__":
    main()

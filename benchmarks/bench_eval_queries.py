"""Paper Figs 8/9: full query evaluation (materialized results) for
{3-4}-path and {3-5}-cycle, plus a representative random-graph query —
host references and the JAX CLFTJ evaluate path (schedule-executor EMIT),
the latter with the plan/compile/exec wall-time split."""
from __future__ import annotations

from repro.core import (choose_plan, clftj_evaluate, engine, lftj_evaluate,
                        ytd_evaluate, path_query, cycle_query,
                        random_graph_query)
from repro.data.graphs import dataset

from .common import run_engine_result, run_ref


def main() -> None:
    for ds in ("wiki-vote-like", "gnutella-like"):
        db = dataset(ds)
        queries = [("3-path", path_query(3)), ("4-path", path_query(4)),
                   ("3-cycle", cycle_query(3)), ("4-cycle", cycle_query(4)),
                   ("5-cycle", cycle_query(5)),
                   ("5-rand(0.4)", random_graph_query(5, 0.4, seed=1))]
        for qname, q in queries:
            td, order = choose_plan(q, db.stats())
            run_ref(f"fig8/{ds}/{qname}/lftj-eval",
                    lambda c: len(lftj_evaluate(q, order, db, c)))
            run_ref(f"fig8/{ds}/{qname}/clftj-eval",
                    lambda c: len(clftj_evaluate(q, td, order, db, None, c)))
            run_ref(f"fig8/{ds}/{qname}/ytd-eval",
                    lambda c: len(ytd_evaluate(q, td, db, c)))
            run_engine_result(
                f"fig8/{ds}/{qname}/jax-clftj-eval",
                lambda: engine.evaluate(q, db, algorithm="clftj",
                                        backend="jax", td=td, order=order,
                                        capacity=1 << 14))


if __name__ == "__main__":
    main()

"""Paper Figs 8/9: full query evaluation (materialized results) for
{3-4}-path and {3-5}-cycle, plus a representative random-graph query —
host references and the JAX CLFTJ evaluate path (schedule-executor EMIT),
the latter with the plan/compile/exec wall-time split.

Two tier-2 variants of every JAX evaluation run: ``nocache`` (the PR-2
bypass baseline) and ``payload`` (row-block caching, DESIGN.md §2.6).  The
``recur`` section is the paper §3.4 evaluation claim made measurable: the
recurring-bag zigzag cycles on the Zipf-skewed IMDB-analogue, where
capacity ≪ frontier forces many parent morsels per span and later morsels
replay earlier morsels' factorized blocks (``replay_hits`` in the derived
column / BENCH json)."""
from __future__ import annotations

from repro.core import (CacheConfig, bowtie_query, choose_plan,
                        clftj_evaluate, engine, lftj_evaluate,
                        ytd_evaluate, path_query, cycle_query,
                        random_graph_query)
from repro.data.graphs import dataset

from .bench_td_skew import TDS, zigzag_cycle
from .common import run_engine_result, run_jax_eval, run_ref

PAYLOAD = CacheConfig(policy="setassoc", slots=1 << 14, assoc=8,
                      cache_payloads=True, payload_rows=1 << 17)


def fig8_sweep() -> None:
    for ds in ("wiki-vote-like", "gnutella-like"):
        db = dataset(ds)
        queries = [("3-path", path_query(3)), ("4-path", path_query(4)),
                   ("3-cycle", cycle_query(3)), ("4-cycle", cycle_query(4)),
                   ("5-cycle", cycle_query(5)),
                   ("5-rand(0.4)", random_graph_query(5, 0.4, seed=1))]
        for qname, q in queries:
            td, order = choose_plan(q, db.stats())
            run_ref(f"fig8/{ds}/{qname}/lftj-eval",
                    lambda c: len(lftj_evaluate(q, order, db, c)))
            run_ref(f"fig8/{ds}/{qname}/clftj-eval",
                    lambda c: len(clftj_evaluate(q, td, order, db, None, c)))
            run_ref(f"fig8/{ds}/{qname}/ytd-eval",
                    lambda c: len(ytd_evaluate(q, td, db, c)))
            run_engine_result(
                f"fig8/{ds}/{qname}/jax-clftj-eval-nocache",
                lambda: engine.evaluate(q, db, algorithm="clftj",
                                        backend="jax", td=td, order=order,
                                        capacity=1 << 14))
            run_engine_result(
                f"fig8/{ds}/{qname}/jax-clftj-eval-payload",
                lambda: engine.evaluate(q, db, algorithm="clftj",
                                        backend="jax", td=td, order=order,
                                        capacity=1 << 14, cache=PAYLOAD))


def small_skewed_db():
    """A scaled-down skewed_db (same Zipf shape): full-size zigzag
    evaluation materializes tens of millions of tuples — counting-bench
    territory, not a materialization benchmark."""
    from repro.core.db import Database
    from repro.data.graphs import zipf_bipartite
    male = zipf_bipartite(800, 500, 2500, 1.3, 0.4, seed=6)
    female = zipf_bipartite(800, 500, 2500, 1.3, 0.4, seed=7)
    return Database({"male_cast": male, "female_cast": female})


def recurring_bag_sweep(capacity: int = 1 << 11) -> dict:
    """Evaluation on the recurring-bag workloads (the skewed zigzag cycle
    and the clique-style bowtie): payload caching vs the cache-off
    baseline, each engine evaluated twice — ``cold`` pays for block
    storage, ``warm`` is the recurring-subjoin case the cache exists for
    (paper §3.4): the whole bag replays from the slab.  Returns
    {name: seconds}."""
    from repro.core.cached_frontier import JaxCachedTrieJoin
    from repro.data.graphs import barabasi_albert
    from repro.core.db import graph_db

    q4 = zigzag_cycle(4)
    td4 = TDS[4]["TD1-person"]
    td4.validate(q4)
    cases = [("4-zigzag", q4, td4, td4.strongly_compatible_order(),
              small_skewed_db())]
    qb = bowtie_query()
    dbb = graph_db(barabasi_albert(600, 5, seed=9))
    tdb, orderb = choose_plan(qb, dbb.stats())
    cases.append(("bowtie", qb, tdb, orderb, dbb))

    out = {}
    for name, q, td, order, db in cases:
        for tag, cache in (("nocache", CacheConfig(slots=0)),
                           ("payload", PAYLOAD)):
            eng = JaxCachedTrieJoin(q, td, order, db, capacity=capacity,
                                    cache=cache)
            for phase in ("cold", "warm"):
                rec = run_jax_eval(
                    f"recur/{name}/jax-clftj-eval-{tag}-{phase}", eng)
                out[f"{name}/{tag}/{phase}"] = rec["seconds"]
    return out


def main() -> None:
    fig8_sweep()
    recurring_bag_sweep()


if __name__ == "__main__":
    main()

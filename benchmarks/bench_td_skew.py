"""Paper Fig 13/14: isomorphic TDs, different cached attributes.

IMDB-analogue zigzag cycles over (male_cast, female_cast): odd variables
bind the skewed person attribute, even variables the flatter movie
attribute.  TD1 keys caches on persons (skewed: high hit rate), TD2 on
movies; plus vanilla LFTJ under each TD's imposed variable order.
"""
from __future__ import annotations

import numpy as np

from repro.core import Atom, CQ, TreeDecomposition, clftj_count, lftj_count
from repro.core.db import Database
from repro.data.graphs import zipf_bipartite

from .common import run_ref

F = frozenset


def zigzag_cycle(n: int) -> CQ:
    """male(x1,x2), female(x3,x2), male(x3,x4), ... female(x1,xn):
    odd vars = persons (col 0), even vars = movies (col 1)."""
    assert n % 2 == 0
    atoms = []
    for i in range(1, n, 2):
        atoms.append(Atom("male_cast", (f"x{i}", f"x{i + 1}")))
        atoms.append(Atom("female_cast",
                          (f"x{(i + 2) if i + 2 <= n else 1}", f"x{i + 1}")))
    return CQ(tuple(atoms))


TDS = {
    4: {
        "TD1-person": TreeDecomposition(
            [F("x1 x2 x3".split()), F("x1 x3 x4".split())], [-1, 0]),
        "TD2-movie": TreeDecomposition(
            [F("x1 x2 x4".split()), F("x2 x3 x4".split())], [-1, 0]),
    },
    6: {
        "TD1-person": TreeDecomposition(
            [F("x1 x3 x5".split()), F("x1 x2 x3".split()),
             F("x3 x4 x5".split()), F("x1 x5 x6".split())], [-1, 0, 0, 0]),
        "TD2-movie": TreeDecomposition(
            [F("x2 x4 x6".split()), F("x1 x2 x6".split()),
             F("x2 x3 x4".split()), F("x4 x5 x6".split())], [-1, 0, 0, 0]),
    },
}


def skewed_db(a_person: float = 1.3, a_movie: float = 0.4) -> Database:
    """The Fig 13/14 IMDB-analogue: person attribute Zipf-skewed, movie
    attribute flatter — shared by the cache-size/structure benchmarks."""
    male = zipf_bipartite(4000, 2500, 12000, a_person, a_movie, seed=6)
    female = zipf_bipartite(4000, 2500, 12000, a_person, a_movie, seed=7)
    return Database({"male_cast": male, "female_cast": female})


def main() -> None:
    db = skewed_db()
    for n in (4, 6):
        q = zigzag_cycle(n)
        for tdname, td in TDS[n].items():
            td.validate(q)
            order = td.strongly_compatible_order()
            run_ref(f"fig13/{n}-cycle/clftj-{tdname}",
                    lambda c: clftj_count(q, td, order, db, None, c))
            run_ref(f"fig13/{n}-cycle/lftj-order-{tdname}",
                    lambda c: lftj_count(q, order, db, c))
        run_ref(f"fig13/{n}-cycle/lftj-default-order",
                lambda c: lftj_count(q, tuple(q.variables), db, c))


if __name__ == "__main__":
    main()

"""Fused-EXPAND kernel microbench + end-to-end dispatch check (§2.7).

Two sections:

* ``expandk/micro`` — one realistic EXPAND step per chunk-size point:
  per-call wall time and the **device-op count** (non-metadata jaxpr
  primitives, ``kernels.registry.device_op_count``) for the fused Pallas
  kernel vs the XLA op chain.  The acceptance bound lives here: fused
  must lower to ≤2 device ops per EXPAND.  On CPU the fused kernel runs
  through the Pallas interpreter (recorded as ``interpret: true``) — its
  wall time is a conformance-vehicle number, not a perf claim; the op
  count is the figure that transfers to TPU/GPU.
* ``expandk/e2e`` — end-to-end count + evaluate on the recurring-bag
  queries (bowtie on a Barabási–Albert graph; the 4-zigzag on the small
  Zipf-skewed DB) with ``expand_kernel="auto"`` vs ``"xla"`` forced: the
  dispatch layer must cost nothing (on CPU auto resolves to the XLA
  chain, so the pair must match — "no end-to-end regression"), plus one
  small forced-``pallas`` bowtie run to keep the interpret-mode cost
  honest in the record.
"""
from __future__ import annotations

import time

import numpy as np
from jax.experimental import enable_x64

from repro.core import CacheConfig, bowtie_query, choose_plan, cycle_query, engine
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.core.db import graph_db
from repro.kernels import registry
from repro.kernels.expand import fused as fused_mod, xla as xla_mod

from .common import emit

CAPS = (1 << 10, 1 << 12, 1 << 14)


def _zipf_db(nv=40, ne=400, a=1.1, seed=31):
    from repro.data.graphs import zipf_graph
    return graph_db(zipf_graph(nv, ne, a, seed=seed))


def _time_call(fn, F, reps=5):
    import jax
    jax.block_until_ready(fn(F))  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(F))
        best = min(best, time.perf_counter() - t0)
    return best


def micro_sweep() -> None:
    """One depth-1 EXPAND (two membership atoms on the 4-cycle) on a
    frontier produced by a real depth-0 expansion, per chunk size."""
    import jax
    db = _zipf_db()
    q = cycle_query(4)
    td, order = choose_plan(q, db.stats())
    interpret = jax.default_backend() not in ("tpu", "gpu")
    with enable_x64():
        for cap in CAPS:
            eng = JaxCachedTrieJoin(q, td, order, db, capacity=cap)
            a0 = eng.expand_kernel_args(0)
            a1 = eng.expand_kernel_args(1)
            F = xla_mod.build(impl="bsearch", **a0)(eng.initial_frontier())[0]
            n_valid = int(np.asarray(F.valid).sum())
            fns = {"xla": xla_mod.build(impl="bsearch", **a1),
                   "pallas": fused_mod.build(**a1)}
            ops = {}
            for impl, fn in fns.items():
                ops[impl] = registry.device_op_count(fn, F)
                dt = _time_call(fn, F)
                emit(f"expandk/micro/cap{cap}/{impl}", dt * 1e6,
                     f"device_ops={ops[impl]};valid_rows={n_valid};"
                     f"interpret={interpret if impl == 'pallas' else False}",
                     record={"kind": "expand-kernel", "cap": cap,
                             "impl": impl, "seconds": dt,
                             "device_ops": ops[impl],
                             "valid_rows": n_valid,
                             "interpret": (interpret if impl == "pallas"
                                           else False)})
            assert ops["pallas"] <= 2, \
                f"fused EXPAND lowered to {ops['pallas']} device ops"


def _best_engine_run(name: str, mk, reps: int = 5,
                     exec_unreliable: bool = False) -> dict:
    """Best-of-``reps`` engine facade run (fresh engine per rep; jit
    caches warm after the first, so the min isolates host-loop noise —
    single-shot exec_s jitter on these queries is ±50%, far larger than
    any real auto-vs-xla delta).  ``exec_unreliable`` marks configs whose
    compile/exec split cannot be trusted — interpret-mode Pallas emits
    compile events *during* execution, so the listener drains exec_s —
    and reports wall − plan (compile + exec) instead, flagged."""
    results = [mk() for _ in range(reps)]
    res = min(results, key=lambda r: r.wall_s)
    s = res.counters or {}
    exec_s, clamped = res.exec_s, False
    if exec_unreliable or exec_s == 0.0:
        exec_s, clamped = max(0.0, res.wall_s - res.plan_s), True
    emit(name, exec_s * 1e6,
         f"count={res.count};exec_s={exec_s:.4f};"
         f"paths={res.expand_paths};replay_hits={res.tier2_replay_hits}",
         record={"kind": "engine", "result": res.count,
                 "seconds": res.wall_s, "plan_s": res.plan_s,
                 "compile_s": res.compile_s, "exec_s": exec_s,
                 "exec_includes_compile": clamped,
                 "reps": reps, "algorithm": res.algorithm,
                 "backend": res.backend, **s})
    return {"exec_s": exec_s, "paths": res.expand_paths}


def e2e_recurring() -> None:
    """End-to-end recurring-bag queries: auto vs forced-xla must match
    (CPU dispatch picks xla), so the kernel subsystem costs nothing
    until an accelerator is present."""
    from repro.data.graphs import barabasi_albert
    from .bench_td_skew import TDS, zigzag_cycle
    from .bench_eval_queries import small_skewed_db

    pay = CacheConfig(policy="setassoc", slots=1 << 14, assoc=8,
                      cache_payloads=True, payload_rows=1 << 17)
    qb = bowtie_query()
    dbb = graph_db(barabasi_albert(600, 5, seed=9))
    q4 = zigzag_cycle(4)
    td4 = TDS[4]["TD1-person"]
    cases = [("bowtie", qb, dbb, None, None),
             ("4-zigzag", q4, small_skewed_db(), td4,
              td4.strongly_compatible_order())]

    def runners(q, db, td, order, kind):
        def count(mode):
            return engine.count(q, db, td=td, order=order,
                                capacity=1 << 11, expand_kernel=mode)

        def ev(mode):
            return engine.evaluate(q, db, algorithm="clftj", backend="jax",
                                   td=td, order=order, capacity=1 << 11,
                                   cache=pay, expand_kernel=mode)

        return count if kind == "count" else ev

    reps = 5
    for name, q, db, td, order in cases:
        for kind in ("count", "eval"):
            mk = runners(q, db, td, order, kind)
            # interleave the two modes so each rep's pair shares the
            # host's momentary load — this box drifts far more than any
            # real auto-vs-xla delta (on CPU both resolve to the same
            # fn, which identical_dispatch pins via the path counters)
            pairs = [(mk("xla"), mk("auto")) for _ in range(reps)]
            best_x = min(pairs, key=lambda p: p[0].wall_s)[0]
            best_a = min(pairs, key=lambda p: p[1].wall_s)[1]
            for tag, res in (("xla", best_x), ("auto", best_a)):
                s = res.counters or {}
                emit(f"expandk/e2e/{name}/{kind}-{tag}",
                     res.exec_s * 1e6,
                     f"count={res.count};exec_s={res.exec_s:.4f};"
                     f"paths={res.expand_paths};"
                     f"replay_hits={res.tier2_replay_hits}",
                     record={"kind": "engine", "result": res.count,
                             "seconds": res.wall_s, "plan_s": res.plan_s,
                             "compile_s": res.compile_s,
                             "exec_s": res.exec_s, "reps": reps,
                             "algorithm": res.algorithm,
                             "backend": res.backend, **s})
            ratios = sorted(a.exec_s / max(x.exec_s, 1e-9)
                            for x, a in pairs)
            ratio = ratios[len(ratios) // 2]  # median of paired ratios
            same = best_a.expand_paths == best_x.expand_paths
            auto_s, xla_s = best_a.exec_s, best_x.exec_s
            emit(f"expandk/e2e/{name}/{kind}-auto-vs-xla",
                 (auto_s - xla_s) * 1e6,
                 f"auto_s={auto_s:.4f};xla_s={xla_s:.4f};"
                 f"median_pair_ratio={ratio:.3f};"
                 f"identical_dispatch={same}",
                 record={"kind": "expand-e2e-delta", "query": name,
                         "mode": kind, "auto_s": auto_s, "xla_s": xla_s,
                         "ratio": ratio, "identical_dispatch": same,
                         "pair_ratios": [round(r, 3) for r in ratios]})
    # interpret-mode honesty record: one small forced-pallas end-to-end.
    # Per-call the fused step beats the XLA chain even on CPU (the
    # interpreter traces to one jitted fusion and skips the argsort
    # compaction — see expandk/micro), but its compile cost is much
    # higher and its compile/exec split unmeasurable, which is why CPU
    # "auto" stays on xla; the time reported here is wall − plan.
    _best_engine_run(
        "expandk/e2e/bowtie/count-pallas-interpret",
        lambda: engine.count(qb, dbb, capacity=1 << 11,
                             expand_kernel="pallas"),
        exec_unreliable=True)


def main() -> None:
    micro_sweep()
    e2e_recurring()


if __name__ == "__main__":
    main()

"""Beyond-paper: host reference CLFTJ vs the vectorized JAX engine, and the
engine's cache-tier ablation (dedup / persistent table / both / none).
This is the measured §Perf series for the join engine."""
from __future__ import annotations

from repro.core import (CacheConfig, choose_plan, clftj_count, cycle_query,
                        path_query)
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.data.graphs import dataset

from .common import run_jax_cached, run_ref


def main() -> None:
    for ds in ("wiki-vote-like", "ego-twitter-like"):
        db = dataset(ds)
        for qname, q in (("5-path", path_query(5)),
                         ("5-cycle", cycle_query(5))):
            td, order = choose_plan(q, db.stats())
            run_ref(f"engine/{ds}/{qname}/ref-clftj",
                    lambda c: clftj_count(q, td, order, db, None, c))
            off = CacheConfig(slots=0)
            on = CacheConfig(slots=1 << 16)
            for label, kw in (
                    ("none", dict(dedup=False, cache=off)),
                    ("dedup", dict(dedup=True, cache=off)),
                    ("table", dict(dedup=False, cache=on)),
                    ("both", dict(dedup=True, cache=on))):
                eng = JaxCachedTrieJoin(q, td, order, db,
                                        capacity=1 << 14, **kw)
                # warm-up compile, then measure (tier stats land in the
                # JSON record via run_jax_cached)
                eng.count()
                eng2 = JaxCachedTrieJoin(q, td, order, db,
                                         capacity=1 << 14, **kw)
                run_jax_cached(f"engine/{ds}/{qname}/jax-{label}", eng2)


if __name__ == "__main__":
    main()

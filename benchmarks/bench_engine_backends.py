"""Beyond-paper: host reference CLFTJ vs the vectorized JAX engine, and the
engine's cache-tier ablation (dedup / persistent table / both / none).
This is the measured §Perf series for the join engine."""
from __future__ import annotations

from repro.core import choose_plan, clftj_count, cycle_query, path_query
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.data.graphs import dataset

from .common import run_jax, run_ref


def main() -> None:
    for ds in ("wiki-vote-like", "ego-twitter-like"):
        db = dataset(ds)
        for qname, q in (("5-path", path_query(5)),
                         ("5-cycle", cycle_query(5))):
            td, order = choose_plan(q, db.stats())
            run_ref(f"engine/{ds}/{qname}/ref-clftj",
                    lambda c: clftj_count(q, td, order, db, None, c))
            for label, kw in (
                    ("none", dict(dedup=False, cache_slots=0)),
                    ("dedup", dict(dedup=True, cache_slots=0)),
                    ("table", dict(dedup=False, cache_slots=1 << 16)),
                    ("both", dict(dedup=True, cache_slots=1 << 16))):
                eng = JaxCachedTrieJoin(q, td, order, db,
                                        capacity=1 << 14, **kw)
                # warm-up compile, then measure
                eng.count()
                stats0 = dict(eng.stats)
                eng2 = JaxCachedTrieJoin(q, td, order, db,
                                         capacity=1 << 14, **kw)
                r = run_jax(f"engine/{ds}/{qname}/jax-{label}", eng2.count)
                r["tier1"] = eng2.stats["tier1_rows_collapsed"]


if __name__ == "__main__":
    main()

"""Paper Fig 11/12: {3,2}-lollipop with cache structures CS1/CS2/CS3.

All three TDs have width 2; they differ in adhesion *dimensions* —
demonstrating that CLFTJ should target small adhesions, not just treewidth.
The JAX section runs the same structures through the vectorized engine's
pluggable tier-2 cache (``CacheConfig``), reporting per-structure hit rates
so the device policies can be compared on identical plans.
"""
from __future__ import annotations

from repro.core import (CacheConfig, TreeDecomposition, clftj_count,
                        lftj_count, lollipop_query)
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.data.graphs import dataset

from .common import run_jax_cached, run_ref

F = frozenset

# lollipop: clique x1x2x3 + path x3-x4-x5
CS = {
    # one 1-dim cache (adhesion {x3})
    "CS1": TreeDecomposition([F("x1 x2 x3".split()), F("x3 x4 x5".split())],
                             [-1, 0]),
    # two 1-dim caches ({x3}, {x4})
    "CS2": TreeDecomposition([F("x1 x2 x3".split()), F("x3 x4".split()),
                              F("x4 x5".split())], [-1, 0, 1]),
    # one 2-dim ({x2,x3}) + one 1-dim ({x4})
    "CS3": TreeDecomposition([F("x1 x2 x3".split()), F("x2 x3 x4".split()),
                              F("x4 x5".split())], [-1, 0, 1]),
}

JAX_CONFIGS = (
    ("direct", CacheConfig(policy="direct", slots=1024)),
    ("assoc4", CacheConfig(policy="setassoc", slots=1024, assoc=4)),
    ("cost4", CacheConfig(policy="costaware", slots=1024, assoc=4)),
)


def main() -> None:
    q = lollipop_query(3, 2)
    for ds in ("wiki-vote-like", "ego-facebook-like"):
        db = dataset(ds)
        order0 = tuple(q.variables)
        run_ref(f"fig11/{ds}/lftj",
                lambda c: lftj_count(q, order0, db, c))
        for name, td in CS.items():
            td.validate(q)
            order = td.strongly_compatible_order()
            run_ref(f"fig11/{ds}/clftj-{name}",
                    lambda c: clftj_count(q, td, order, db, None, c))
            for pname, cfg in JAX_CONFIGS:
                eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 12,
                                        cache=cfg)
                run_jax_cached(f"fig11jax/{ds}/clftj-{name}-{pname}", eng)


if __name__ == "__main__":
    main()

"""Paper Fig 7: {3-6}-cycle count scaling.  3-cycle (triangle) has no
nontrivial TD, so CLFTJ degenerates to LFTJ — same runtimes expected."""
from __future__ import annotations

from repro.core import (choose_plan, clftj_count, lftj_count, ytd_count,
                        cycle_query)
from repro.data.graphs import dataset

from .common import run_ref


def main() -> None:
    for ds in ("wiki-vote-like", "ego-facebook-like"):
        db = dataset(ds)
        for n in range(3, 7):
            q = cycle_query(n)
            td, order = choose_plan(q, db.stats())
            run_ref(f"fig7/{ds}/{n}-cycle/lftj",
                    lambda c: lftj_count(q, order, db, c))
            run_ref(f"fig7/{ds}/{n}-cycle/clftj",
                    lambda c: clftj_count(q, td, order, db, None, c))
            run_ref(f"fig7/{ds}/{n}-cycle/ytd",
                    lambda c: ytd_count(q, td, db, c))


if __name__ == "__main__":
    main()

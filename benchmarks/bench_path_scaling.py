"""Paper Fig 6: {3-7}-path count scaling (CLFTJ's speedup grows with query
size; vanilla LFTJ times out on the big ones, as in the paper)."""
from __future__ import annotations

from repro.core import (choose_plan, clftj_count, lftj_count, ytd_count,
                        path_query)
from repro.data.graphs import dataset

from .common import run_ref


def main() -> None:
    for ds in ("wiki-vote-like", "ego-facebook-like"):
        db = dataset(ds)
        for n in range(3, 8):
            q = path_query(n)
            td, order = choose_plan(q, db.stats())
            run_ref(f"fig6/{ds}/{n}-path/lftj",
                    lambda c: lftj_count(q, order, db, c))
            run_ref(f"fig6/{ds}/{n}-path/clftj",
                    lambda c: clftj_count(q, td, order, db, None, c))
            run_ref(f"fig6/{ds}/{n}-path/ytd",
                    lambda c: ytd_count(q, td, db, c))


if __name__ == "__main__":
    main()

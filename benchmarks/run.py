"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Module map:
  bench_count_queries   — Fig 5 (+§1 memory-access analysis)
  bench_path_scaling    — Fig 6
  bench_cycle_scaling   — Fig 7
  bench_eval_queries    — Figs 8/9 (+ JAX CLFTJ materialization)
  bench_cache_size      — Fig 10
  bench_cache_structure — Figs 11/12
  bench_td_skew         — Figs 13/14
  bench_engine_backends — beyond-paper: vectorized engine + tier ablation
  bench_expand_kernel   — fused-EXPAND kernel: device-op counts + e2e deltas
  bench_serve           — query-serving latency: cold vs plan-cache-warm
                          vs snapshot-loaded persistent-warm (DESIGN §2.9)
  bench_lm_step         — LM substrate wall-clock micro-bench

``--json [PATH]`` additionally writes every emitted row as structured
records (count + evaluate wall-times with the plan/compile/exec split,
tier-2 hit rates) to ``BENCH_<date>.json`` — the perf trajectory file.
"""
import argparse
import datetime
import json
import platform
import sys

MODULES = [
    "bench_count_queries", "bench_path_scaling", "bench_cycle_scaling",
    "bench_eval_queries", "bench_cache_size", "bench_cache_structure",
    "bench_td_skew", "bench_engine_backends", "bench_expand_kernel",
    "bench_stream_emit", "bench_serve", "bench_lm_step",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write structured records to PATH "
                         "(default BENCH_<date>.json)")
    args = ap.parse_args()
    mods = MODULES if not args.only else [
        m for m in MODULES if any(s in m for s in args.only.split(","))]
    print("name,us_per_call,derived")
    errors = []
    for m in mods:
        print(f"# --- {m} ---", flush=True)
        mod = __import__(f"benchmarks.{m}", fromlist=["main"])
        try:
            mod.main()
        except Exception as e:     # keep the harness running
            errors.append({"module": m, "error": str(e)})
            print(f"{m},0,ERROR:{e}", flush=True)
    if args.json is not None:
        from . import common
        import jax
        date = datetime.date.today().isoformat()
        path = args.json or f"BENCH_{date}.json"
        payload = {
            "date": date,
            "modules": mods,
            "platform": platform.platform(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "errors": errors,
            "rows": common.RECORDS,
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(common.RECORDS)} records -> {path}", flush=True)


if __name__ == "__main__":
    main()

"""Query serving across processes (DESIGN.md §2.9).

    PYTHONPATH=src python examples/serve_join.py

Runs the two-process demo end to end:

* **process A** opens a :func:`repro.core.engine.serve` server, answers a
  few isomorphic queries (the second is a plan-cache hit — same compiled
  engine, warm tier-2 tables), streams one concurrently, and writes a
  snapshot of the warm state;
* **process B** — a genuinely separate interpreter — loads the snapshot
  and shows that its *first* query is already warm: plan-cache hit,
  ``tier2_replay_hits > 0``, identical answers.

Pass ``a``/``b`` as argv[1] to run one side manually (e.g. on two
machines sharing a filesystem).
"""
import os
import subprocess
import sys
import tempfile

import numpy as np

from repro.core import path_query
from repro.core.cq import CQ, Atom
from repro.core.db import graph_db
from repro.core.engine import serve

SNAP = os.environ.get("SERVE_SNAP",
                      os.path.join(tempfile.gettempdir(), "serve_join.npz"))

# E(x,y) ⋈ E(y,z) ⋈ E(z,w) — and an isomorphic copy a client might send
# (vars renamed a/z/b/q, atoms reordered: same join, same plan-cache key)
Q = path_query(4)
Q_ISO = CQ((Atom("E", ("b", "q")), Atom("E", ("z", "b")),
            Atom("E", ("a", "z"))))


def make_db():
    rng = np.random.default_rng(7)
    return graph_db(rng.integers(0, 120, size=(900, 2)))


def process_a() -> None:
    with serve(make_db()) as srv:
        r1 = srv.evaluate(Q)
        r2 = srv.evaluate(Q)          # same shape: plan-cache hit + replay
        print(f"A: q1 hit={r1.plan_cache_hit} rows={len(r1.tuples)} "
              f"wall={r1.wall_s:.2f}s")
        print(f"A: q2 hit={r2.plan_cache_hit} rows={len(r2.tuples)} "
              f"replay={r2.tier2_replay_hits} wall={r2.wall_s:.2f}s")
        sess = srv.evaluate_stream(Q)  # concurrent streaming session
        n = sum(b.shape[0] for b in sess.blocks())
        print(f"A: streamed {n} rows in order {sess.result().order}")
        srv.save_snapshot(SNAP)
        print(f"A: snapshot -> {SNAP} ({os.path.getsize(SNAP)} bytes)")


def process_b() -> None:
    with serve(make_db()) as srv:
        summary = srv.load_snapshot(SNAP)
        print(f"B: loaded {summary}")
        r = srv.evaluate(Q_ISO)        # FIRST query, isomorphic renaming
        print(f"B: first query hit={r.plan_cache_hit} "
              f"replay={r.tier2_replay_hits} rows={len(r.tuples)} "
              f"wall={r.wall_s:.2f}s")
        assert r.plan_cache_hit and r.tier2_replay_hits > 0
        print("B: warm across the process boundary ✓")


def main() -> None:
    if len(sys.argv) > 1:
        {"a": process_a, "b": process_b}[sys.argv[1]]()
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    for phase in ("a", "b"):
        subprocess.run([sys.executable, __file__, phase], env=env,
                       check=True)


if __name__ == "__main__":
    main()

"""Distributed CLFTJ across devices: shard_map over top-level candidate
runs, private per-shard caches, a single count psum (DESIGN.md §3).

    PYTHONPATH=src python examples/distributed_join.py --devices 8
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--dataset", default="gnutella-like")  # balanced degrees; on skewed
# graphs equal-run sharding can overflow the hub shard (see EXPERIMENTS §Perf)
args = ap.parse_args()
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           f" --xla_force_host_platform_device_count={args.devices}")

import jax                              # noqa: E402 (after XLA_FLAGS)
import time                             # noqa: E402
from repro.core import choose_plan, cycle_query, lftj_count  # noqa: E402
from repro.core.distributed import make_distributed_count    # noqa: E402
from repro.data.graphs import dataset   # noqa: E402


def main() -> None:
    db = dataset(args.dataset)
    q = cycle_query(4)
    td, order = choose_plan(q, db.stats())
    mesh = jax.make_mesh((args.devices, 1), ("data", "model"))
    fn, eng = make_distributed_count(q, td, order, db, mesh,
                                     capacity=1 << 17,
                                     axes=("data", "model"))
    with mesh:
        t0 = time.perf_counter()
        total, overflow = fn()
        total.block_until_ready()
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        total, overflow = fn()
        total.block_until_ready()
        dt2 = time.perf_counter() - t0
    print(f"devices={args.devices}  count={int(total)}  "
          f"overflow_shards={int(overflow)}")
    if int(overflow):
        raise SystemExit("static capacity overflow — rerun with a larger "
                         "capacity (the host-driven engine splits morsels "
                         "automatically; the SPMD pipeline flags instead)")
    print(f"first call (incl. compile): {dt:.2f}s; steady-state: {dt2:.3f}s")
    want = lftj_count(q, order, db)
    assert int(total) == want, (int(total), want)
    print(f"matches host reference ({want})")


if __name__ == "__main__":
    main()

"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoint/resume, with visibly decreasing loss on a learnable stream.

    PYTHONPATH=src python examples/train_lm.py                  # ci preset
    PYTHONPATH=src python examples/train_lm.py --preset full    # ~100M model
"""
import argparse
import shutil

from repro.configs.base import ArchConfig
from repro.data.tokens import DataConfig
from repro.models import Model
from repro.optim.adamw import OptConfig
from repro.train.loop import LoopConfig, train
from repro.train.train_step import TrainConfig

PRESETS = {
    # runs in minutes on one CPU core
    "ci": dict(cfg=ArchConfig(name="ci-28m", family="dense", n_layers=4,
                              d_model=256, n_heads=4, n_kv_heads=2,
                              d_ff=1024, vocab=8192, head_dim=64),
               batch=8, seq=128, steps=120),
    # ~100M params; a few hundred steps (sized for a real machine)
    "full": dict(cfg=ArchConfig(name="lm-100m", family="dense", n_layers=12,
                                d_model=640, n_heads=10, n_kv_heads=5,
                                d_ff=2560, vocab=50048, head_dim=64),
                 batch=32, seq=512, steps=300),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=list(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    cfg: ArchConfig = p["cfg"]
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    model = Model(cfg)
    print(f"model {cfg.name}: {model.param_count()/1e6:.1f}M params")
    data = DataConfig(vocab=cfg.vocab, seq_len=p["seq"],
                      global_batch=p["batch"], seed=0)
    hist = train(
        model, data,
        TrainConfig(microbatches=2,
                    opt=OptConfig(lr=1e-3, warmup_steps=20,
                                  decay_steps=p["steps"])),
        LoopConfig(total_steps=p["steps"], ckpt_every=50, log_every=10,
                   ckpt_dir=args.ckpt_dir))
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"({len(hist['loss'])} steps, "
          f"{1e3 * sum(hist['step_time'])/len(hist['step_time']):.0f} "
          f"ms/step)")


if __name__ == "__main__":
    main()

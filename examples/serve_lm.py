"""Batched serving driver: prefill a batch of prompts, then greedy-decode
with per-kind KV caches (dense / ring / recurrent states).

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b-smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import Model
from repro.train.serve_step import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (args.batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    t0 = time.perf_counter()
    out = greedy_generate(model, params, batch, steps=args.steps)
    dt = time.perf_counter() - t0
    toks = args.batch * args.steps
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"steps={args.steps}")
    print(f"generated:\n{out}")
    print(f"{toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()

"""Quickstart: plan and run a cached trie join (the paper's CLFTJ).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CachePolicy, Counters, choose_plan, clftj_count,
                        cycle_query, path_query, graph_db, lftj_count, engine)
from repro.data.graphs import dataset


def main() -> None:
    # a skewed graph (ego-Twitter-like) and the paper's flagship 5-cycle
    db = dataset("wiki-vote-like")
    q = path_query(4)
    print(f"query: {q}")

    # 1) plan: enumerate TDs (small adhesions first), pick one + a strongly
    #    compatible variable order
    td, order = choose_plan(q, db.stats())
    print(f"TD bags: {[sorted(b) for b in td.bags]}")
    print(f"adhesions: {[sorted(td.adhesion(v)) for v in range(td.num_nodes) if td.parent[v] >= 0]}")
    print(f"order: {order}")

    # 2) vanilla LFTJ (paper Fig 1) vs cached CLFTJ (paper Fig 2)
    c_l = Counters()
    n_l = lftj_count(q, order, db, c_l)
    c_c = Counters()
    n_c = clftj_count(q, td, order, db, CachePolicy(), c_c)
    assert n_l == n_c
    print(f"\n|q(D)| = {n_l}")
    print(f"LFTJ  memory accesses: {c_l.mem_accesses:>12,}")
    print(f"CLFTJ memory accesses: {c_c.mem_accesses:>12,} "
          f"({c_l.mem_accesses / max(c_c.mem_accesses, 1):.1f}x fewer; "
          f"{c_c.cache_hits} cache hits)")

    # 3) the TPU-native vectorized engine (same counts, one line)
    res = engine.count(q, db)
    assert res.count == n_l
    print(f"JAX engine count: {res.count}  ({res.wall_s:.2f}s, "
          f"tier-1 rows collapsed: {res.counters['tier1_rows_collapsed']:,})")


if __name__ == "__main__":
    main()

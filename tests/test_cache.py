"""Tier-2 cache subsystem (core/cache.py): policy × size correctness and
accounting invariants.

The paper's flexibility property is that caching is *optional*: any policy
at any size (including 0 = disabled) must produce exactly the count of the
cache-free engine.  The accounting invariant hits + misses == probes is
what the dynamic sizing controller steers on, so it is load-bearing."""
import numpy as np
import pytest

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import (CacheConfig, CachePolicy, choose_plan, clftj_count,
                        cycle_query, lftj_count, lollipop_query, star_query)
from repro.core.cache import DeviceCache
from repro.core.cached_frontier import JaxCachedTrieJoin

POLICY_CONFIGS = [
    CacheConfig(policy="direct", slots=0),          # disabled
    CacheConfig(policy="direct", slots=64),
    CacheConfig(policy="direct", slots=1 << 12),
    CacheConfig(policy="setassoc", slots=64, assoc=4),
    CacheConfig(policy="setassoc", slots=1 << 12, assoc=8),
    CacheConfig(policy="costaware", slots=64, assoc=2),
    CacheConfig(policy="costaware", slots=1 << 12, assoc=4),
    CacheConfig(policy="setassoc", slots=64, assoc=4, dynamic=True,
                budget=1 << 12, min_slots=16, resize_interval=2),
]


def _ids(cfg: CacheConfig) -> str:
    tag = f"{cfg.policy}-s{cfg.slots}-w{cfg.ways}"
    return tag + ("-dyn" if cfg.dynamic else "")


@pytest.mark.parametrize("cfg", POLICY_CONFIGS, ids=_ids)
@pytest.mark.parametrize("qf", [lambda: cycle_query(5),
                                lambda: lollipop_query(3, 2),
                                lambda: star_query(3)])
def test_policy_and_size_never_change_counts(small_graphs, cfg, qf):
    """Every policy × slots point == the cache-free, dedup-free engine."""
    q = qf()
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    baseline = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9,
                                 dedup=False,
                                 cache=CacheConfig(slots=0)).count()
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, cache=cfg)
    assert eng.count() == baseline


@pytest.mark.parametrize("cfg", POLICY_CONFIGS, ids=_ids)
def test_probe_accounting_invariant(small_graphs, cfg):
    """tier2_hits + tier2_misses == tier2_probes, for every policy."""
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, cache=cfg)
    eng.count()
    s = eng.stats
    assert s["tier2_hits"] + s["tier2_misses"] == s["tier2_probes"]
    if cfg.slots == 0:
        assert s["tier2_probes"] == 0 and s["tier2_slots"] == 0


def test_dynamic_sizing_respects_budget_and_resizes(small_graphs):
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    cfg = CacheConfig(policy="setassoc", slots=16, assoc=4, dynamic=True,
                      budget=256, min_slots=8, resize_interval=1,
                      grow_below_hit_rate=1.0)  # always under target → grow
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8, cache=cfg)
    want = lftj_count(q, order, db)
    assert eng.count() == want
    assert eng.stats["tier2_resizes"] > 0
    # hard budget (the one-set-per-node floor is far below 256 here)
    assert eng.cache.total_slots() <= 256
    for t in eng.cache.tables.values():
        assert t.n_slots <= cfg.max_slots


def test_device_cache_set_fills_all_ways_and_hits():
    """A batch of same-set keys must fill every way, not just one (the
    multi-round insert), and then hit on re-probe."""
    with enable_x64():
        from repro.core.cache import _hash_sets
        cfg = CacheConfig(policy="setassoc", slots=16, assoc=4)
        t = DeviceCache.create(cfg)
        n_sets = t.keys.shape[0]
        ks, k = [], 1
        while len(ks) < 4:  # 4 distinct keys, all in set 0
            if int(_hash_sets(jnp.asarray([k], jnp.int64), n_sets)[0]) == 0:
                ks.append(k)
            k += 1
        keys = jnp.asarray(ks, jnp.int64)
        vals = jnp.arange(4, dtype=jnp.int64) + 10
        t.insert(keys, vals, jnp.ones(4, bool))
        assert t.occupancy() == 4 and bool(t.used[0].all())
        hit, got = t.probe(keys, jnp.ones(4, bool))
        assert bool(hit.all())
        assert np.asarray(got).tolist() == [10, 11, 12, 13]
        assert t.hits + t.misses == t.probes == 4


def test_device_cache_lru_evicts_oldest():
    with enable_x64():
        from repro.core.cache import _hash_sets
        cfg = CacheConfig(policy="setassoc", slots=8, assoc=2)
        t = DeviceCache.create(cfg)
        n_sets = t.keys.shape[0]
        ks, k = [], 1
        while len(ks) < 3:
            if int(_hash_sets(jnp.asarray([k], jnp.int64), n_sets)[0]) == 0:
                ks.append(k)
            k += 1
        one = jnp.ones(1, bool)
        t.insert(jnp.asarray(ks[:1], jnp.int64), jnp.asarray([1], jnp.int64),
                 one)
        t.insert(jnp.asarray(ks[1:2], jnp.int64), jnp.asarray([2], jnp.int64),
                 one)
        t.probe(jnp.asarray(ks[:1], jnp.int64), one)   # touch key0 → key1 LRU
        t.insert(jnp.asarray(ks[2:3], jnp.int64), jnp.asarray([3], jnp.int64),
                 one)                                   # evicts key1
        hit0, _ = t.probe(jnp.asarray(ks[:1], jnp.int64), one)
        hit1, _ = t.probe(jnp.asarray(ks[1:2], jnp.int64), one)
        hit2, _ = t.probe(jnp.asarray(ks[2:3], jnp.int64), one)
        assert bool(hit0[0]) and bool(hit2[0]) and not bool(hit1[0])
        assert t.evictions == 1


def test_device_cache_costaware_protects_expensive():
    with enable_x64():
        from repro.core.cache import _hash_sets
        cfg = CacheConfig(policy="costaware", slots=4, assoc=1)
        t = DeviceCache.create(cfg)
        n_sets = t.keys.shape[0]
        ks, k = [], 1
        while len(ks) < 2:
            if int(_hash_sets(jnp.asarray([k], jnp.int64), n_sets)[0]) == 0:
                ks.append(k)
            k += 1
        one = jnp.ones(1, bool)
        t.insert(jnp.asarray(ks[:1], jnp.int64),
                 jnp.asarray([1000], jnp.int64), one)   # expensive resident
        t.insert(jnp.asarray(ks[1:2], jnp.int64),
                 jnp.asarray([1], jnp.int64), one)      # cheap: refused
        hit0, v = t.probe(jnp.asarray(ks[:1], jnp.int64), one)
        hit1, _ = t.probe(jnp.asarray(ks[1:2], jnp.int64), one)
        assert bool(hit0[0]) and int(v[0]) == 1000 and not bool(hit1[0])


def test_tier1_dedup_independent_of_tier2(small_graphs):
    """slots=0 disables only tier 2 — tier-1 dedup must still run."""
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 10,
                            cache=CacheConfig(slots=0), dedup=True)
    assert eng.count() == lftj_count(q, order, db)
    assert eng.stats["tier1_rows_collapsed"] > 0
    assert eng.stats["tier2_probes"] == 0


def test_sub_associativity_slots_round_up_to_one_set(small_graphs):
    """A positive slots request below one set must not silently disable
    the cache."""
    cfg = CacheConfig(policy="setassoc", slots=2, assoc=4)
    assert cfg.initial_slots() == 4
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, cache=cfg)
    assert eng.count() == lftj_count(q, order, db)
    assert eng.stats["tier2_probes"] > 0


def test_ref_engine_cost_policy_matches(small_graphs):
    """Host-engine analogue: 'cost' eviction preserves counts too."""
    q = cycle_query(5)
    db = small_graphs[1]
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    for cap in (0, 2, 8):
        pol = CachePolicy(capacity=cap, evict="cost")
        assert clftj_count(q, td, order, db, pol) == want


def test_cache_policy_from_cache_config():
    pol = CachePolicy.from_cache_config(
        CacheConfig(policy="costaware", slots=128, assoc=4))
    assert pol.evict == "cost" and pol.capacity == 128
    pol = CachePolicy.from_cache_config(
        CacheConfig(policy="setassoc", slots=64, budget=32))
    assert pol.evict == "lru" and pol.capacity == 32


# ---------------------------------------------------------------------------
# Row-block payload region (DESIGN.md §2.6)
# ---------------------------------------------------------------------------

def _payload_table(slots=8, assoc=2, payload_rows=32, policy="setassoc"):
    cfg = CacheConfig(policy=policy, slots=slots, assoc=assoc,
                      cache_payloads=True, payload_rows=payload_rows)
    t = DeviceCache.create(cfg)
    t.ensure_slab(width=2)
    return t


def _np(x):
    return np.asarray(x)


@pytest.mark.tier1
def test_payload_roundtrip_and_count_only_miss():
    """A payload insert is hit by probe_payload; a count-only insert on the
    same table is NOT (the -1 sentinel) while the plain probe still hits."""
    with enable_x64():
        t = _payload_table()
        keys = jnp.asarray([3, 4], jnp.int64)
        active = jnp.asarray([True, True])
        lens = jnp.asarray([2, 0], jnp.int64)
        poff_np, admit = t.alloc_blocks(_np(lens), _np(active))
        assert list(admit) == [True, False]
        t.slab = t.slab.at[poff_np[0]:poff_np[0] + 2].set(
            jnp.asarray([[7, 8], [9, 10]], jnp.int32))
        t.insert(keys, lens, jnp.asarray(admit),
                 poff=jnp.asarray(poff_np), plen=lens.astype(jnp.int32))
        hit, poff, plen = t.probe_payload(keys, active)
        assert list(_np(hit)) == [True, False]
        assert int(_np(plen)[0]) == 2
        block = _np(t.slab)[int(_np(poff)[0]):int(_np(poff)[0]) + 2]
        assert block.tolist() == [[7, 8], [9, 10]]
        # count-only insert of a NEW key on the same table: plain probe
        # hits it, payload probe refuses it
        t.insert(jnp.asarray([5, 0], jnp.int64), jnp.asarray([6, 0]),
                 jnp.asarray([True, False]))
        hit2, vals2 = t.probe(jnp.asarray([5, 0], jnp.int64),
                              jnp.asarray([True, False]))
        assert list(_np(hit2)) == [True, False] and int(_np(vals2)[0]) == 6
        hit3, _, _ = t.probe_payload(jnp.asarray([5, 0], jnp.int64),
                                     jnp.asarray([True, False]))
        assert list(_np(hit3)) == [False, False]


@pytest.mark.tier1
def test_payload_flush_on_arena_exhaustion():
    """When a batch exceeds the remaining arena the table epoch-flushes:
    every payload is invalidated, keys/counts stay resident."""
    with enable_x64():
        t = _payload_table(payload_rows=8)
        k1 = jnp.asarray([11, 12], jnp.int64)
        lens = jnp.asarray([4, 4], jnp.int64)
        act = jnp.asarray([True, True])
        poff_np, admit = t.alloc_blocks(_np(lens), _np(act))
        assert list(admit) == [True, True] and t.slab_bump == 8
        t.insert(k1, lens, jnp.asarray(admit), poff=jnp.asarray(poff_np),
                 plen=lens.astype(jnp.int32))
        # next batch cannot fit → flush, then admit from offset 0
        poff2, admit2 = t.alloc_blocks(np.asarray([6, 0]),
                                       np.asarray([True, False]))
        assert t.payload_flushes == 1 and list(admit2) == [True, False]
        assert poff2[0] == 0 and t.slab_bump == 6
        hit, _, _ = t.probe_payload(k1, act)
        assert not _np(hit).any(), "flushed payloads must not hit"
        hit_c, vals = t.probe(k1, act)
        assert list(_np(hit_c)) == [True, True]
        assert list(_np(vals)) == [4, 4], "counts survive the flush"


@pytest.mark.tier1
def test_payload_eviction_invalidates_block_metadata():
    """An evicting write must take the payload planes with it: after a
    count-only insert evicts a payload entry (direct-mapped, same set),
    the new key must not inherit the victim's block."""
    with enable_x64():
        cfg = CacheConfig(policy="direct", slots=1, cache_payloads=True,
                          payload_rows=16)
        t = DeviceCache.create(cfg)
        t.ensure_slab(width=2)
        one = jnp.asarray([True])
        k_old = jnp.asarray([21], jnp.int64)
        lens = jnp.asarray([3], jnp.int64)
        poff_np, admit = t.alloc_blocks(_np(lens), _np(one))
        t.insert(k_old, lens, jnp.asarray(admit), poff=jnp.asarray(poff_np),
                 plen=lens.astype(jnp.int32))
        assert _np(t.probe_payload(k_old, one)[0]).all()
        # count-only insert of a different key lands in the only slot
        k_new = jnp.asarray([22], jnp.int64)
        t.insert(k_new, jnp.asarray([9], jnp.int64), one)
        hit_new, _, _ = t.probe_payload(k_new, one)
        assert not _np(hit_new).any(), "stale block reachable under new key"
        hit_old, _, _ = t.probe_payload(k_old, one)
        assert not _np(hit_old).any()


@pytest.mark.tier1
def test_payload_attaches_to_count_only_resident():
    """A payload-bearing insert may refresh a key first seen by count():
    afterwards the payload probe hits it."""
    with enable_x64():
        t = _payload_table(slots=8, assoc=2)
        one = jnp.asarray([True])
        k = jnp.asarray([31], jnp.int64)
        t.insert(k, jnp.asarray([5], jnp.int64), one)  # count-only
        assert not _np(t.probe_payload(k, one)[0]).any()
        lens = jnp.asarray([2], jnp.int64)
        poff_np, admit = t.alloc_blocks(_np(lens), _np(one))
        t.insert(k, lens, jnp.asarray(admit), poff=jnp.asarray(poff_np),
                 plen=lens.astype(jnp.int32))
        hit, _, plen = t.probe_payload(k, one)
        assert _np(hit).all() and int(_np(plen)[0]) == 2


def test_payload_survives_dynamic_resize(small_graphs):
    """The sizing controller's rehash carries payload metadata; answers and
    the accounting invariant hold with payloads + dynamic sizing."""
    q = star_query(3)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    cfg = CacheConfig(policy="setassoc", slots=16, assoc=4, dynamic=True,
                      budget=512, min_slots=8, resize_interval=1,
                      grow_below_hit_rate=1.0, cache_payloads=True,
                      payload_rows=1 << 12)
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8, cache=cfg)
    n1 = sum(b.shape[0] for b in eng.evaluate())
    n2 = sum(b.shape[0] for b in eng.evaluate())
    assert n1 == n2 == lftj_count(q, order, db)
    s = eng.stats
    assert s["tier2_hits"] + s["tier2_misses"] == s["tier2_probes"]
    assert s["tier2_replay_hits"] > 0


@pytest.mark.tier1
def test_payload_store_throttle():
    """A table with many evaluation probes and a negligible payload hit
    rate must throttle block storage; a recovering rate re-opens it."""
    cfg = CacheConfig(policy="setassoc", slots=64, assoc=4,
                      cache_payloads=True, payload_rows=64,
                      payload_throttle_probes=1000,
                      payload_throttle_hit_rate=0.01)
    t = DeviceCache.create(cfg)
    t.eval_probes_h, t.eval_hits_h = 500, 0
    assert not t.store_throttled(), "below the probe floor"
    t.eval_probes_h = 2000
    assert t.store_throttled(), "0% hits past the floor"
    t.eval_hits_h = 200
    assert not t.store_throttled(), "recovered hit rate re-opens storage"


def test_payload_throttle_end_to_end_still_correct(small_graphs):
    """With the throttle forced on from the first fold (floor 0) and
    probation off, answers are unchanged, the throttle is visibly
    engaged, and nothing is ever stored."""
    q = star_query(3)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    cfg = CacheConfig(policy="setassoc", slots=256, assoc=4,
                      cache_payloads=True, payload_rows=1 << 12,
                      payload_throttle_probes=0,
                      payload_throttle_hit_rate=1.0,
                      payload_probation=0)
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8, cache=cfg)
    n1 = sum(b.shape[0] for b in eng.evaluate())
    n2 = sum(b.shape[0] for b in eng.evaluate())
    assert n1 == n2 == lftj_count(q, order, db)
    assert eng.stats["tier2_payload_throttled"] > 0
    assert eng.stats["tier2_slab_rows"] == 0, "throttle must stop stores"


@pytest.mark.tier1
def test_payload_dedup_off_no_duplicate_blocks(small_graphs):
    """With tier-1 dedup off, duplicate adhesion keys in one chunk must
    not each burn arena rows: one block per distinct key is stored, and
    answers still match."""
    q = star_query(3)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    cfg = CacheConfig(policy="setassoc", slots=256, assoc=4,
                      cache_payloads=True, payload_rows=1 << 13)
    on = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, dedup=True,
                           cache=cfg)
    off = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, dedup=False,
                            cache=cfg)
    assert sum(b.shape[0] for b in on.evaluate()) == want
    assert sum(b.shape[0] for b in off.evaluate()) == want
    # duplicate keys collapse host-side: dedup-off stores the same arena
    # volume as dedup-on (no per-duplicate leak)
    assert off.stats["tier2_slab_rows"] == on.stats["tier2_slab_rows"]
    assert sum(b.shape[0] for b in off.evaluate()) == want
    assert off.stats["tier2_replay_hits"] > 0


@pytest.mark.tier1
def test_alloc_oversized_block_neither_flushes_nor_vetoes():
    """A block larger than the whole arena is refused outright: it must
    not epoch-flush resident payloads nor veto admissible candidates
    behind it in the same batch."""
    with enable_x64():
        t = _payload_table(payload_rows=8)
        t.alloc_blocks(np.asarray([3]), np.asarray([True]))  # bump = 3
        # a never-fit block alone must not flush resident payloads
        _, admit0 = t.alloc_blocks(np.asarray([99]), np.asarray([True]))
        assert list(admit0) == [False] and t.payload_flushes == 0
        # ...nor veto an admissible candidate behind it in the same batch
        offs, admit = t.alloc_blocks(np.asarray([99, 2]),
                                     np.asarray([True, True]))
        assert list(admit) == [False, True]
        assert t.payload_flushes == 0 and offs[1] == 3
        # a batch that genuinely needs space still flushes, and after the
        # flush its first candidate is guaranteed to admit
        offs2, admit2 = t.alloc_blocks(np.asarray([7]), np.asarray([True]))
        assert t.payload_flushes == 1 and list(admit2) == [True]
        assert offs2[0] == 0


@pytest.mark.tier1
def test_throttled_table_still_shrinks_under_dynamic_sizing(small_graphs):
    """The sizing controller must keep running while the store throttle
    is engaged: an insert-less (fully throttled) table with near-zero
    occupancy hands its slots back."""
    q = star_query(3)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    cfg = CacheConfig(policy="setassoc", slots=256, assoc=4, dynamic=True,
                      min_slots=8, resize_interval=1,
                      cache_payloads=True, payload_rows=1 << 12,
                      payload_throttle_probes=0,
                      payload_throttle_hit_rate=1.0, payload_probation=0)
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8, cache=cfg)
    n = sum(b.shape[0] for b in eng.evaluate())
    assert n == lftj_count(q, order, db)
    assert eng.stats["tier2_payload_throttled"] > 0
    assert eng.stats["tier2_resizes"] > 0, "controller frozen while throttled"
    assert eng.stats["tier2_slots"] < 256, "empty table did not shrink"

"""Tier-2 cache subsystem (core/cache.py): policy × size correctness and
accounting invariants.

The paper's flexibility property is that caching is *optional*: any policy
at any size (including 0 = disabled) must produce exactly the count of the
cache-free engine.  The accounting invariant hits + misses == probes is
what the dynamic sizing controller steers on, so it is load-bearing."""
import numpy as np
import pytest

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import (CacheConfig, CachePolicy, choose_plan, clftj_count,
                        cycle_query, lftj_count, lollipop_query, star_query)
from repro.core.cache import DeviceCache
from repro.core.cached_frontier import JaxCachedTrieJoin

POLICY_CONFIGS = [
    CacheConfig(policy="direct", slots=0),          # disabled
    CacheConfig(policy="direct", slots=64),
    CacheConfig(policy="direct", slots=1 << 12),
    CacheConfig(policy="setassoc", slots=64, assoc=4),
    CacheConfig(policy="setassoc", slots=1 << 12, assoc=8),
    CacheConfig(policy="costaware", slots=64, assoc=2),
    CacheConfig(policy="costaware", slots=1 << 12, assoc=4),
    CacheConfig(policy="setassoc", slots=64, assoc=4, dynamic=True,
                budget=1 << 12, min_slots=16, resize_interval=2),
]


def _ids(cfg: CacheConfig) -> str:
    tag = f"{cfg.policy}-s{cfg.slots}-w{cfg.ways}"
    return tag + ("-dyn" if cfg.dynamic else "")


@pytest.mark.parametrize("cfg", POLICY_CONFIGS, ids=_ids)
@pytest.mark.parametrize("qf", [lambda: cycle_query(5),
                                lambda: lollipop_query(3, 2),
                                lambda: star_query(3)])
def test_policy_and_size_never_change_counts(small_graphs, cfg, qf):
    """Every policy × slots point == the cache-free, dedup-free engine."""
    q = qf()
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    baseline = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9,
                                 dedup=False,
                                 cache=CacheConfig(slots=0)).count()
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, cache=cfg)
    assert eng.count() == baseline


@pytest.mark.parametrize("cfg", POLICY_CONFIGS, ids=_ids)
def test_probe_accounting_invariant(small_graphs, cfg):
    """tier2_hits + tier2_misses == tier2_probes, for every policy."""
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, cache=cfg)
    eng.count()
    s = eng.stats
    assert s["tier2_hits"] + s["tier2_misses"] == s["tier2_probes"]
    if cfg.slots == 0:
        assert s["tier2_probes"] == 0 and s["tier2_slots"] == 0


def test_dynamic_sizing_respects_budget_and_resizes(small_graphs):
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    cfg = CacheConfig(policy="setassoc", slots=16, assoc=4, dynamic=True,
                      budget=256, min_slots=8, resize_interval=1,
                      grow_below_hit_rate=1.0)  # always under target → grow
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8, cache=cfg)
    want = lftj_count(q, order, db)
    assert eng.count() == want
    assert eng.stats["tier2_resizes"] > 0
    # hard budget (the one-set-per-node floor is far below 256 here)
    assert eng.cache.total_slots() <= 256
    for t in eng.cache.tables.values():
        assert t.n_slots <= cfg.max_slots


def test_device_cache_set_fills_all_ways_and_hits():
    """A batch of same-set keys must fill every way, not just one (the
    multi-round insert), and then hit on re-probe."""
    with enable_x64():
        from repro.core.cache import _hash_sets
        cfg = CacheConfig(policy="setassoc", slots=16, assoc=4)
        t = DeviceCache.create(cfg)
        n_sets = t.keys.shape[0]
        ks, k = [], 1
        while len(ks) < 4:  # 4 distinct keys, all in set 0
            if int(_hash_sets(jnp.asarray([k], jnp.int64), n_sets)[0]) == 0:
                ks.append(k)
            k += 1
        keys = jnp.asarray(ks, jnp.int64)
        vals = jnp.arange(4, dtype=jnp.int64) + 10
        t.insert(keys, vals, jnp.ones(4, bool))
        assert t.occupancy() == 4 and bool(t.used[0].all())
        hit, got = t.probe(keys, jnp.ones(4, bool))
        assert bool(hit.all())
        assert np.asarray(got).tolist() == [10, 11, 12, 13]
        assert t.hits + t.misses == t.probes == 4


def test_device_cache_lru_evicts_oldest():
    with enable_x64():
        from repro.core.cache import _hash_sets
        cfg = CacheConfig(policy="setassoc", slots=8, assoc=2)
        t = DeviceCache.create(cfg)
        n_sets = t.keys.shape[0]
        ks, k = [], 1
        while len(ks) < 3:
            if int(_hash_sets(jnp.asarray([k], jnp.int64), n_sets)[0]) == 0:
                ks.append(k)
            k += 1
        one = jnp.ones(1, bool)
        t.insert(jnp.asarray(ks[:1], jnp.int64), jnp.asarray([1], jnp.int64),
                 one)
        t.insert(jnp.asarray(ks[1:2], jnp.int64), jnp.asarray([2], jnp.int64),
                 one)
        t.probe(jnp.asarray(ks[:1], jnp.int64), one)   # touch key0 → key1 LRU
        t.insert(jnp.asarray(ks[2:3], jnp.int64), jnp.asarray([3], jnp.int64),
                 one)                                   # evicts key1
        hit0, _ = t.probe(jnp.asarray(ks[:1], jnp.int64), one)
        hit1, _ = t.probe(jnp.asarray(ks[1:2], jnp.int64), one)
        hit2, _ = t.probe(jnp.asarray(ks[2:3], jnp.int64), one)
        assert bool(hit0[0]) and bool(hit2[0]) and not bool(hit1[0])
        assert t.evictions == 1


def test_device_cache_costaware_protects_expensive():
    with enable_x64():
        from repro.core.cache import _hash_sets
        cfg = CacheConfig(policy="costaware", slots=4, assoc=1)
        t = DeviceCache.create(cfg)
        n_sets = t.keys.shape[0]
        ks, k = [], 1
        while len(ks) < 2:
            if int(_hash_sets(jnp.asarray([k], jnp.int64), n_sets)[0]) == 0:
                ks.append(k)
            k += 1
        one = jnp.ones(1, bool)
        t.insert(jnp.asarray(ks[:1], jnp.int64),
                 jnp.asarray([1000], jnp.int64), one)   # expensive resident
        t.insert(jnp.asarray(ks[1:2], jnp.int64),
                 jnp.asarray([1], jnp.int64), one)      # cheap: refused
        hit0, v = t.probe(jnp.asarray(ks[:1], jnp.int64), one)
        hit1, _ = t.probe(jnp.asarray(ks[1:2], jnp.int64), one)
        assert bool(hit0[0]) and int(v[0]) == 1000 and not bool(hit1[0])


def test_tier1_dedup_independent_of_tier2(small_graphs):
    """slots=0 disables only tier 2 — tier-1 dedup must still run."""
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 10,
                            cache=CacheConfig(slots=0), dedup=True)
    assert eng.count() == lftj_count(q, order, db)
    assert eng.stats["tier1_rows_collapsed"] > 0
    assert eng.stats["tier2_probes"] == 0


def test_sub_associativity_slots_round_up_to_one_set(small_graphs):
    """A positive slots request below one set must not silently disable
    the cache."""
    cfg = CacheConfig(policy="setassoc", slots=2, assoc=4)
    assert cfg.initial_slots() == 4
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, cache=cfg)
    assert eng.count() == lftj_count(q, order, db)
    assert eng.stats["tier2_probes"] > 0


def test_ref_engine_cost_policy_matches(small_graphs):
    """Host-engine analogue: 'cost' eviction preserves counts too."""
    q = cycle_query(5)
    db = small_graphs[1]
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    for cap in (0, 2, 8):
        pol = CachePolicy(capacity=cap, evict="cost")
        assert clftj_count(q, td, order, db, pol) == want


def test_cache_policy_from_cache_config():
    pol = CachePolicy.from_cache_config(
        CacheConfig(policy="costaware", slots=128, assoc=4))
    assert pol.evict == "cost" and pol.capacity == 128
    pol = CachePolicy.from_cache_config(
        CacheConfig(policy="setassoc", slots=64, budget=32))
    assert pol.evict == "lru" and pol.capacity == 32

"""Schedule IR: lowering rules, executor equivalence, deprecation shims,
and the Result timing split.

The lowered op list is the single source of control flow for all three
engines (host LFTJ, host CLFTJ, distributed static CLFTJ) — these tests
pin its structural invariants so an engine can trust the schedule instead
of re-deriving the TD recursion."""
import numpy as np
import pytest

from repro.core import (CacheConfig, Op, Schedule, choose_plan, clftj_count,
                        cycle_query, engine, lftj_count, lower, path_query,
                        star_query)
from repro.core.cached_frontier import JaxCachedTrieJoin, jax_clftj_count
from repro.core.clftj_ref import Plan
from repro.core.db import graph_db
from repro.core.schedule import EMIT, ENTER_CHILD, EXPAND, FOLD_CHILD


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(99)
    return graph_db(rng.integers(0, 14, size=(90, 2)))


# -- lowering ---------------------------------------------------------------

def test_trivial_lowering_is_expand_then_emit():
    s = lower(4)
    assert [op.kind for op in s.ops] == [EXPAND] * 4 + [EMIT]
    assert [op.d for op in s.ops[:-1]] == [0, 1, 2, 3]


def test_td_lowering_brackets_and_depths(db):
    q = path_query(4)
    td, order = choose_plan(q, db.stats())
    plan = Plan.build(td, order)
    s = lower(len(order), plan=plan, cacheable=lambda c: True)
    # every EXPAND depth appears exactly once, in order
    assert [op.d for op in s.ops if op.kind == EXPAND] == list(
        range(len(order)))
    # ENTER/FOLD bracket properly per node and FOLD knows its subtree span
    opens = []
    for op in s.ops:
        if op.kind == ENTER_CHILD:
            opens.append(op.node)
        elif op.kind == FOLD_CHILD:
            assert opens.pop() == op.node
            assert 0 <= op.sub_first <= op.sub_last < len(order)
            assert op.adhesion == tuple(plan.adhesion_idx[op.node])
    assert not opens and s.ops[-1].kind == EMIT
    # one ENTER per non-root TD node
    n_children = sum(1 for v in range(td.num_nodes) if td.parent[v] >= 0)
    assert sum(1 for op in s.ops if op.kind == ENTER_CHILD) == n_children


def test_lowering_flags_follow_cacheable_and_dedup(db):
    q = cycle_query(5)
    td, order = choose_plan(q, db.stats())
    plan = Plan.build(td, order)
    s_on = lower(len(order), plan=plan, cacheable=lambda c: True, dedup=True)
    s_off = lower(len(order), plan=plan, cacheable=lambda c: False,
                  dedup=True)
    s_nod = lower(len(order), plan=plan, cacheable=lambda c: True,
                  dedup=False)
    for op in s_on.ops:
        if op.kind == ENTER_CHILD:
            assert op.probe and op.dedup
    for op in s_off.ops:
        if op.kind == ENTER_CHILD:
            assert not op.probe and not op.dedup
    for op in s_nod.ops:
        if op.kind == ENTER_CHILD:
            assert op.probe and not op.dedup


def test_schedule_validation_rejects_malformed():
    with pytest.raises(ValueError):
        Schedule((Op(EXPAND, d=0), Op(EMIT)), n=2)      # missing depth 1
    with pytest.raises(ValueError):
        Schedule((Op(EXPAND, d=0),), n=1)               # no EMIT
    with pytest.raises(ValueError):
        Schedule((Op(EXPAND, d=0), Op(ENTER_CHILD, node=1), Op(EMIT)), n=1)


def test_engine_schedule_is_shared_control_flow(db):
    """The engine instance carries exactly one lowered schedule, and its
    describe() names every op — the op list IS the plan artifact."""
    q = star_query(3)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9)
    text = eng.schedule.describe()
    assert "EXPAND" in text and "EMIT" in text
    if td.num_nodes > 1:
        assert "ENTER_CHILD" in text and "FOLD_CHILD" in text


# -- executor equivalence on a nested (multi-bag) TD ------------------------

def test_executor_count_matches_reference_on_nested_td(db):
    for qf in (path_query(5), star_query(4), cycle_query(5)):
        td, order = choose_plan(qf, db.stats())
        want = lftj_count(qf, order, db)
        assert clftj_count(qf, td, order, db) == want
        eng = JaxCachedTrieJoin(qf, td, order, db, capacity=1 << 9)
        assert eng.count() == want


# -- the removed cache_slots shim stays removed -----------------------------

def test_cache_slots_shim_removed_everywhere(db):
    """PR 2 deprecated the legacy ``cache_slots`` int for one release;
    the shim is now deleted end-to-end — every entry point rejects the
    parameter outright, and ``cache=CacheConfig(...)`` is the only
    tier-2 configuration surface."""
    q = cycle_query(4)
    td, order = choose_plan(q, db.stats())
    with pytest.raises(TypeError, match="cache_slots"):
        JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, cache_slots=64)
    with pytest.raises(TypeError, match="cache_slots"):
        jax_clftj_count(q, td, order, db, capacity=1 << 9, cache_slots=64)
    with pytest.raises(TypeError, match="cache_slots"):
        engine.count(q, db, td=td, order=order, cache_slots=64)
    from repro.core.distributed import make_distributed_count
    with pytest.raises(TypeError, match="cache_slots"):
        make_distributed_count(q, td, order, db, mesh=None, cache_slots=64)
    # the replacement surface still works
    cfg = CacheConfig(policy="direct", slots=64)
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 9, cache=cfg)
    assert eng.count() == lftj_count(q, order, db)
    assert eng.cache_config is cfg


# -- Result timing split ----------------------------------------------------

def test_result_separates_plan_compile_exec(db):
    q = cycle_query(4)
    res = engine.count(q, db, capacity=1 << 9)
    assert res.plan_s >= 0 and res.compile_s >= 0 and res.exec_s >= 0
    assert res.wall_s == pytest.approx(
        res.plan_s + res.compile_s + res.exec_s, abs=5e-3)
    # a second run with the same shapes reuses the jit cache: compile time
    # must (essentially) vanish while the answer is unchanged
    res2 = engine.count(q, db, capacity=1 << 9)
    assert res2.count == res.count
    assert res2.compile_s <= max(0.05, res.compile_s)

"""Cross-engine equivalence: LFTJ / CLFTJ / YTD / brute force (counts and
materialized results), plus cache-policy variants (paper Figs 1, 2, §5.1)."""
import numpy as np
import pytest

from repro.core import (CachePolicy, choose_plan, clftj_count,
                        clftj_evaluate, lftj_count, lftj_evaluate,
                        ytd_count, ytd_evaluate, path_query, cycle_query,
                        lollipop_query, random_graph_query)
from repro.core.bruteforce import brute_force_evaluate

QUERIES = [path_query(4), cycle_query(4), cycle_query(5),
           lollipop_query(3, 2), random_graph_query(5, 0.5, seed=2)]


def _remap(tups, order, variables):
    idx = [list(order).index(x) for x in variables]
    return {tuple(t[i] for i in idx) for t in tups}


@pytest.mark.parametrize("qi", range(len(QUERIES)))
def test_counts_and_evals_agree(small_graphs, qi):
    q = QUERIES[qi]
    for db in small_graphs:
        td, order = choose_plan(q, db.stats())
        want = brute_force_evaluate(q, db)
        assert lftj_count(q, order, db) == len(want)
        assert clftj_count(q, td, order, db) == len(want)
        assert ytd_count(q, td, db) == len(want)
        assert _remap(lftj_evaluate(q, order, db), order,
                      q.variables) == want
        assert _remap(clftj_evaluate(q, td, order, db), order,
                      q.variables) == want
        assert set(map(tuple, ytd_evaluate(q, td, db))) == want


@pytest.mark.parametrize("policy", [
    CachePolicy(support_threshold=2),
    CachePolicy(capacity=4),
    CachePolicy(capacity=2, evict="lru"),
    CachePolicy(capacity=0),
    CachePolicy(enabled_nodes=frozenset({1})),
])
def test_cache_policies_preserve_correctness(small_graphs, policy):
    q = cycle_query(5)
    db = small_graphs[1]
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    assert clftj_count(q, td, order, db, policy) == want
    got = clftj_evaluate(q, td, order, db, policy)
    assert len(got) == want


def test_bounded_cache_bounds_memory(small_graphs):
    from repro.core.clftj_ref import CLFTJ
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    eng = CLFTJ(q, td, order, db, CachePolicy(capacity=3))
    eng.count()
    assert len(eng.cache) <= 3

"""Fault-tolerant training loop: crash/restart equivalence, preemption."""
import shutil

import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.tokens import DataConfig
from repro.models import Model
from repro.optim.adamw import OptConfig
from repro.train.loop import LoopConfig, train
from repro.train.train_step import TrainConfig


def _setup():
    cfg = get_arch("qwen2.5-3b-smoke")
    model = Model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=1)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2,
                                     decay_steps=30))
    return model, data, tcfg


def test_crash_resume_equals_straight_run(tmp_path):
    model, data, tcfg = _setup()
    lcfg1 = LoopConfig(total_steps=12, ckpt_every=6, log_every=100,
                       ckpt_dir=str(tmp_path / "a"))
    h1 = train(model, data, tcfg, lcfg1, log=lambda s: None)
    lcfg2 = LoopConfig(total_steps=12, ckpt_every=6, log_every=100,
                       ckpt_dir=str(tmp_path / "b"))
    with pytest.raises(RuntimeError):
        train(model, data, tcfg, lcfg2, log=lambda s: None, fail_at_step=7)
    h2 = train(model, data, tcfg, lcfg2, log=lambda s: None)
    np.testing.assert_allclose(h1["loss"][-6:], h2["loss"][-6:], rtol=1e-5)


def test_loss_decreases(tmp_path):
    model, data, tcfg = _setup()
    lcfg = LoopConfig(total_steps=25, ckpt_every=100, log_every=100,
                      ckpt_dir=str(tmp_path / "c"))
    h = train(model, data, tcfg, lcfg, log=lambda s: None)
    assert np.mean(h["loss"][-5:]) < np.mean(h["loss"][:5])

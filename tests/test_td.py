"""Tree decompositions: validity, orders, enumeration (paper §2.3, §4).

Property coverage runs under hypothesis when installed; a deterministic
seed corpus keeps the same assertions running on minimal installs."""
import pytest

from repro.core.cq import (clique_query, cycle_query, lollipop_query,
                           path_query, random_graph_query)
from repro.core.decompose import (choose_plan, enumerate_tds,
                                  generic_decompose, td_heuristic_key)
from repro.core.td import TreeDecomposition, singleton_td

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

QUERIES = [path_query(5), cycle_query(5), cycle_query(6),
           lollipop_query(3, 2), clique_query(4),
           random_graph_query(6, 0.5, seed=1)]


@pytest.mark.parametrize("qi", range(len(QUERIES)))
def test_generic_decompose_valid(qi):
    q = QUERIES[qi]
    td = generic_decompose(q)
    td.validate(q)
    order = td.strongly_compatible_order()
    assert td.is_strongly_compatible(order)
    assert td.is_compatible(order)


@pytest.mark.parametrize("qi", range(len(QUERIES)))
def test_enumerate_tds_all_valid(qi):
    q = QUERIES[qi]
    tds = enumerate_tds(q, max_adhesion=2, limit=12)
    assert tds
    for td in tds:
        td.validate(q)
        assert td.is_strongly_compatible(td.strongly_compatible_order())


def test_clique_has_singleton_td():
    q = clique_query(4)
    tds = enumerate_tds(q, max_adhesion=2, limit=4)
    assert all(td.num_nodes == 1 for td in tds), \
        "cliques cannot be decomposed (paper §5.2.2)"


def test_owner_and_adhesion_structure():
    q = cycle_query(5)
    td, order = choose_plan(q)
    owners = td.owners()
    pos = {x: i for i, x in enumerate(order)}
    pre = {v: r for r, v in enumerate(td.preorder())}
    for x, y in zip(order, order[1:]):
        assert pre[owners[x]] <= pre[owners[y]]
    # every non-root owns >= 1 variable (Plan.build requirement)
    owned = set(owners.values())
    for v in range(td.num_nodes):
        if td.parent[v] >= 0:
            assert v in owned


def test_redundant_bag_elimination():
    td = TreeDecomposition(
        [frozenset({"a", "b"}), frozenset({"b"}), frozenset({"b", "c"})],
        [-1, 0, 1])
    out = td.eliminate_redundant_bags()
    assert out.num_nodes == 2


def _check_random_plan(n: int, seed: int) -> None:
    q = random_graph_query(n, 0.5, seed=seed)
    td, order = choose_plan(q)
    td.validate(q)
    assert td.is_strongly_compatible(order)


@pytest.mark.parametrize("n,seed", [(4 + s % 4, 211 + s) for s in range(10)])
def test_corpus_plans_random_graphs(n, seed):
    _check_random_plan(n, seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(st.integers(4, 7), st.integers(0, 10_000))
    def test_property_plans_random_graphs(n, seed):
        _check_random_plan(n, seed)

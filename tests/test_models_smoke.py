"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, asserting output shapes and finiteness (assignment requirement)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.models import Model
from repro.models.transformer import forward


def _batch(cfg, B=2, T=16):
    b = {"tokens": jnp.full((B, T), 3, jnp.int32),
         "targets": jnp.ones((B, T), jnp.int32)}
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.full(
            (B, cfg.n_image_tokens, cfg.d_model), 0.1, jnp.float32)
    if cfg.family == "audio":
        b["audio_embeds"] = jnp.full(
            (B, cfg.encoder_seq, cfg.d_model), 0.1, jnp.float32)
    return b


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward_and_loss(name):
    cfg = get_arch(name + "-smoke")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) == 32


@pytest.mark.parametrize("name", ["minitron-8b", "qwen3-moe-235b-a22b",
                                  "recurrentgemma-2b", "rwkv6-7b"])
def test_smoke_train_step(name):
    from repro.train.train_step import (TrainConfig, init_train_state,
                                        make_train_step)
    cfg = get_arch(name + "-smoke")
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, TrainConfig(microbatches=2)))
    batch = _batch(cfg, B=4, T=16)
    l0 = None
    for i in range(3):
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        l0 = l0 if l0 is not None else float(metrics["loss"])
    assert float(metrics["loss"]) < l0, "loss should fall on a fixed batch"


def test_full_config_param_counts_match_names():
    """The config's parameter count should land near the advertised size."""
    expect = {"minitron-8b": (8, 11), "stablelm-12b": (11, 13),
              "qwen2.5-3b": (2.5, 3.5), "yi-6b": (5.5, 6.5),
              "qwen3-moe-235b-a22b": (230, 240),
              "phi3.5-moe-42b-a6.6b": (40, 44),
              "llama-3.2-vision-90b": (80, 95),
              "rwkv6-7b": (7, 9), "whisper-tiny": (0.03, 0.08),
              "recurrentgemma-2b": (2, 4)}
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count() / 1e9
        assert lo <= n <= hi, (name, n)


def test_moe_active_params():
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    active = cfg.active_param_count() / 1e9
    assert 20 <= active <= 25, active  # "a22b"

"""Property tests: on random databases and random queries, every engine
agrees with brute force — the system's core invariant.

Runs under hypothesis when it is installed; otherwise the same generators
are driven by a fixed deterministic seed corpus so the core assertions
always execute (hypothesis is an optional dev dependency)."""
import numpy as np
import pytest

from repro.core import (CacheConfig, CachePolicy, choose_plan, clftj_count,
                        lftj_count, ytd_count, cycle_query, path_query,
                        random_graph_query)
from repro.core import engine
from repro.core.bruteforce import brute_force_count
from repro.core.db import graph_db

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False


def _make_case(seed: int):
    """Deterministic (db, query) sample — shared by both drivers."""
    rng = np.random.default_rng(seed)
    ne = int(rng.integers(5, 60))
    nv = int(rng.integers(3, 12))
    edges = rng.integers(0, nv, size=(ne, 2))
    kind = ["path", "cycle", "rand"][int(rng.integers(0, 3))]
    if kind == "path":
        q = path_query(int(rng.integers(3, 6)))
    elif kind == "cycle":
        q = cycle_query(int(rng.integers(3, 6)))
    else:
        q = random_graph_query(int(rng.integers(4, 6)), 0.6, seed=seed)
    return graph_db(edges), q


def _assert_engines_match(db, q):
    want = brute_force_count(q, db)
    td, order = choose_plan(q, db.stats())
    assert lftj_count(q, order, db) == want
    assert clftj_count(q, td, order, db) == want
    assert ytd_count(q, td, db) == want


def _assert_bounded_cache_invariant(db, q, cap: int):
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    assert clftj_count(q, td, order, db, CachePolicy(capacity=cap)) == want


# cache configs for the count == |evaluate| property: payloads off, every
# payload-bearing policy, and a slab tiny enough to flush mid-query
_EVAL_CACHES = [
    None,
    CacheConfig(policy="direct", slots=64, cache_payloads=True,
                payload_rows=1 << 11),
    CacheConfig(policy="setassoc", slots=64, assoc=4, cache_payloads=True,
                payload_rows=1 << 11),
    CacheConfig(policy="costaware", slots=64, assoc=4, cache_payloads=True,
                payload_rows=16),
]


def _assert_count_equals_evaluate(db, q):
    """engine.count(...) == len(engine.evaluate(...)) for every engine —
    counting and materialization are the same semantics, whatever the
    algorithm, backend, or tier-2 policy (row-block caching included)."""
    for algorithm, backend in [("lftj", "ref"), ("clftj", "ref"),
                               ("ytd", "ref"), ("lftj", "jax")]:
        c = engine.count(q, db, algorithm=algorithm, backend=backend,
                         capacity=1 << 9)
        e = engine.evaluate(q, db, algorithm=algorithm, backend=backend,
                            capacity=1 << 9)
        assert c.count == len(e.tuples) == e.count, (algorithm, backend)
    for cache in _EVAL_CACHES:
        c = engine.count(q, db, algorithm="clftj", backend="jax",
                         capacity=1 << 9, cache=cache)
        e = engine.evaluate(q, db, algorithm="clftj", backend="jax",
                            capacity=1 << 9, cache=cache)
        assert c.count == len(e.tuples) == e.count, cache


# -- deterministic corpus (always runs) ------------------------------------

CORPUS = list(range(17, 17 + 12))


@pytest.mark.parametrize("seed", CORPUS)
def test_corpus_all_engines_match_bruteforce(seed):
    db, q = _make_case(seed)
    _assert_engines_match(db, q)


@pytest.mark.parametrize("seed,cap", [(s, s % 7) for s in CORPUS[:6]])
def test_corpus_bounded_cache_invariant(seed, cap):
    """Any capacity (even 0) must not change results — caching is optional
    by construction (the paper's 'flexible' property)."""
    db, q = _make_case(seed)
    _assert_bounded_cache_invariant(db, q, cap)


@pytest.mark.slow
@pytest.mark.parametrize("seed", CORPUS[:4])
def test_corpus_count_equals_evaluate(seed):
    """Deterministic fallback of the count == |evaluate| property — runs
    even without hypothesis installed."""
    db, q = _make_case(seed)
    _assert_count_equals_evaluate(db, q)


# -- hypothesis drivers (when installed) -----------------------------------

if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10 ** 6))
    def test_all_engines_match_bruteforce(seed):
        db, q = _make_case(seed)
        _assert_engines_match(db, q)

    @pytest.mark.slow
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10 ** 6), st.integers(0, 6))
    def test_bounded_cache_invariant(seed, cap):
        db, q = _make_case(seed)
        _assert_bounded_cache_invariant(db, q, cap)

    @pytest.mark.slow
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10 ** 6))
    def test_count_equals_evaluate(seed):
        db, q = _make_case(seed)
        _assert_count_equals_evaluate(db, q)

"""Property tests (hypothesis): on random databases and random queries,
every engine agrees with brute force — the system's core invariant."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (CachePolicy, choose_plan, clftj_count, lftj_count,
                        ytd_count, cycle_query, path_query,
                        random_graph_query)
from repro.core.bruteforce import brute_force_count
from repro.core.db import graph_db


@st.composite
def db_and_query(draw):
    seed = draw(st.integers(0, 10 ** 6))
    rng = np.random.default_rng(seed)
    ne = draw(st.integers(5, 60))
    nv = draw(st.integers(3, 12))
    edges = rng.integers(0, nv, size=(ne, 2))
    kind = draw(st.sampled_from(["path", "cycle", "rand"]))
    if kind == "path":
        q = path_query(draw(st.integers(3, 5)))
    elif kind == "cycle":
        q = cycle_query(draw(st.integers(3, 5)))
    else:
        q = random_graph_query(draw(st.integers(4, 5)), 0.6, seed=seed)
    return graph_db(edges), q, seed


@settings(max_examples=25, deadline=None)
@given(db_and_query())
def test_all_engines_match_bruteforce(dq):
    db, q, seed = dq
    want = brute_force_count(q, db)
    td, order = choose_plan(q, db.stats())
    assert lftj_count(q, order, db) == want
    assert clftj_count(q, td, order, db) == want
    assert ytd_count(q, td, db) == want


@settings(max_examples=10, deadline=None)
@given(db_and_query(), st.integers(0, 6))
def test_bounded_cache_invariant(dq, cap):
    """Any capacity (even 0) must not change results — caching is optional
    by construction (the paper's 'flexible' property)."""
    db, q, seed = dq
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    assert clftj_count(q, td, order, db, CachePolicy(capacity=cap)) == want

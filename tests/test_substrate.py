"""Substrate: optimizer, data pipeline, checkpointing, fault runtime,
sharding rules."""
import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.data import tokens as dtok
from repro.checkpoint.ckpt import CheckpointManager
from repro.runtime.fault import PreemptionGuard, StragglerWatch
from repro.sharding import rules as shr


# --- optimizer -------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=0, decay_steps=100,
                          weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_schedule_warmup_and_decay():
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


def test_grad_clipping():
    cfg = adamw.OptConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params)
    _, _, m = adamw.update(cfg, params, {"w": jnp.asarray([30., 40., 0.])},
                           state)
    assert float(m["grad_norm"]) == pytest.approx(50.0)


# --- data ------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = dtok.DataConfig(vocab=97, seq_len=16, global_batch=8, seed=3)
    a = dtok.batch_at(cfg, 5)
    b = dtok.batch_at(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    s0 = dtok.batch_at(cfg, 5, shard=0, num_shards=2)
    s1 = dtok.batch_at(cfg, 5, shard=1, num_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # learnable: most transitions follow the affine map
    t = a["tokens"][:, :-1]
    nxt = a["tokens"][:, 1:]
    frac = np.mean(nxt == (cfg.a * t + cfg.c) % cfg.vocab)
    assert frac > 0.7


# --- checkpointing -----------------------------------------------------------

def test_checkpoint_roundtrip_retention_and_resume(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2, async_save=False)
    state = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, state))
    assert mgr.all_steps() == [2, 3]      # retention
    step, restored, _ = mgr.restore(state)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(5) * 3)


def test_checkpoint_async_and_struct_restore(tmp_path):
    d = str(tmp_path / "ck2")
    mgr = CheckpointManager(d, keep=1, async_save=True)
    state = {"w": jnp.full((4,), 7.0)}
    mgr.save(10, state)
    mgr.wait()
    like = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
    step, restored, _ = mgr.restore(like)
    assert step == 10 and float(restored["w"][0]) == 7.0


# --- fault runtime -----------------------------------------------------------

def test_straggler_watch_flags_slow_steps():
    w = StragglerWatch(factor=3.0)
    for _ in range(10):
        w.observe(0.1)
    assert w.observe(1.0) is True
    assert w.flagged == 1
    assert w.observe(0.1) is False


def test_preemption_guard_stop_request():
    g = PreemptionGuard()
    assert not g.should_stop
    g.request_stop()
    assert g.should_stop


# --- sharding rules ----------------------------------------------------------

def _mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_partition_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # divisible -> sharded on 1-sized axis is pointless; use a fake mesh math
    spec = shr.partition_spec(("vocab", "embed"), (51865, 384), mesh)
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_batch_sharding_divisibility():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # on a 1x1 mesh every batch divides; spec may name the size-1 axis
    assert shr.batch_sharding(mesh, 3).spec in (P(), P("data"), P(("data",)))
    # real divisibility fallback (B=1 on a >1 data axis) is covered by the
    # multi-device subprocess tests in test_distributed.py

"""Host-sync regression guard: the schedule executor batches its chunk
admission, so device→host syncs scale with *op executions* (span
interiors re-run once per parent morsel), never with the number of chunks
inside one op execution.

Every deliberate sync in the engine goes through ``hostsync.device_get``
(the funnel); a :class:`SyncCounter` around a query counts them.  The
budget is derived from the executor's own op-run counters: at most 3
syncs per EXPAND run (planning fetch, split fetch, admission), 1 per FOLD
run (replay planning in evaluate mode), 1 per span close (continuation
admission), plus emission and stats finalization.  If someone
reintroduces a per-chunk ``bool(...)`` these fail with the offending
label in ``events``."""
import numpy as np
import pytest

from repro.core import (CacheConfig, SyncCounter, choose_plan, cycle_query,
                        lftj_count, path_query)
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.core.frontier import JaxTrieJoin


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(1729)
    from repro.core.db import graph_db
    return graph_db(rng.integers(0, 40, size=(400, 2)))


def _budget(eng, stats_slack: int = 6) -> int:
    r = eng.last_executor.op_runs
    return 3 * r["expand"] + r["fold"] + r["span"] + r["emit"] + stats_slack


def test_triangle_stays_under_sync_budget(db):
    q = cycle_query(3)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 12)
    want = lftj_count(q, order, db)
    with SyncCounter() as sc:
        got = eng.count()
    assert got == want
    assert sc.count <= _budget(eng), sc.events


@pytest.mark.parametrize("cap", [1 << 13, 1 << 9, 1 << 7])
def test_sync_budget_scales_with_op_runs_not_chunks(db, cap):
    """Shrinking capacity multiplies the morsel count; syncs must track
    the op-run budget at every capacity (a per-chunk sync would blow it
    as soon as one op execution carries many chunks)."""
    q = cycle_query(3)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=cap)
    with SyncCounter() as sc:
        eng.count()
    assert sc.count <= _budget(eng), (cap, sc.events)


@pytest.mark.parametrize("cap", [1 << 11, 1 << 7])
def test_multibag_td_sync_budget(db, cap):
    """ENTER/FOLD spans add O(1) syncs per parent morsel (probe/dedup/
    insert are all device-side; cache stats accumulate on device) — also
    at a capacity small enough to force multiple parents per span."""
    q = path_query(4)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(
        q, td, order, db, capacity=cap,
        cache=CacheConfig(policy="setassoc", slots=256, assoc=4))
    want = lftj_count(q, order, db)
    with SyncCounter() as sc:
        got = eng.count()
    assert got == want
    assert sc.count <= _budget(eng), sc.events


def test_evaluate_mode_sync_budget(db):
    """Materialization adds one replay-planning fetch per FOLD run and a
    single batched row fetch at the end — still op-run bounded."""
    q = path_query(4)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 10)
    with SyncCounter() as sc:
        blocks = list(eng.evaluate())
    n = sum(b.shape[0] for b in blocks)
    assert n == lftj_count(q, order, db)
    assert sc.count <= _budget(eng), sc.events


@pytest.mark.tier1
def test_evaluate_payload_sync_budget(db):
    """Row-block caching must not add syncs: the payload plan (hit mask +
    block lengths) rides the per-fold ``replay-plan`` fetch — O(ops), not
    O(hits) — and the slab writes/splices are pure device ops.  Checked on
    a warm engine (second pass = replay-on-hit exercised end to end)."""
    q = path_query(4)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(
        q, td, order, db, capacity=1 << 9,
        cache=CacheConfig(policy="setassoc", slots=256, assoc=4,
                          cache_payloads=True, payload_rows=1 << 14))
    n1 = sum(b.shape[0] for b in eng.evaluate())  # cold: fills the slab
    with SyncCounter() as sc:
        n2 = sum(b.shape[0] for b in eng.evaluate())
    assert n1 == n2 == lftj_count(q, order, db)
    assert eng.stats["tier2_replay_hits"] > 0, "payload path not exercised"
    r = eng.last_executor.op_runs
    assert sc.count <= _budget(eng), sc.events
    # payload fetches are batched per fold op, never per hit
    assert sc.label_counts["replay-plan"] <= r["fold"], sc.label_counts


@pytest.mark.tier1
def test_evaluate_stream_sync_budget(db):
    """Streaming emission must keep BLOCKING host syncs O(ops): result
    blocks leave as async fetches (``emit-stream`` issues, counted in
    ``async_count`` and labeled separately in ``label_counts``) — never
    as the one-shot ``emit-rows`` drain, and never as per-block blocking
    syncs.  Totals must still match the one-shot path exactly."""
    q = path_query(4)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(
        q, td, order, db, capacity=1 << 9,
        cache=CacheConfig(policy="setassoc", slots=256, assoc=4,
                          cache_payloads=True, payload_rows=1 << 14))
    n1 = sum(b.shape[0] for b in eng.evaluate())  # warm: fills the slab
    with SyncCounter() as sc:
        n2 = sum(b.shape[0] for b in eng.evaluate_stream())
    assert n1 == n2 == lftj_count(q, order, db)
    assert eng.stats["tier2_replay_hits"] > 0, "payload path not exercised"
    r = eng.last_executor.op_runs
    # blocking budget unchanged — streaming adds no blocking syncs at all
    assert sc.count <= _budget(eng), sc.events
    assert sc.label_counts["emit-rows"] == 0, "one-shot drain in stream mode"
    # every emitted block left through the async queue, labeled as such
    assert sc.label_counts["emit-stream"] == sc.async_count > 0
    assert sc.async_count == eng.last_executor.emitted_blocks
    # payload fetches still batch per fold op, never per hit
    assert sc.label_counts["replay-plan"] <= r["fold"], sc.label_counts


def test_vanilla_lftj_sync_budget(db):
    q = path_query(3)
    order = sorted(q.variables)
    eng = JaxTrieJoin(q, order, db, capacity=1 << 12)
    with SyncCounter() as sc:
        eng.count()
    assert sc.count <= _budget(eng, stats_slack=2), sc.events

"""Query-serving tier (DESIGN.md §2.9): the lock-down suite for
``repro/serve``.

Five groups:

* **canonical keys** — plan-cache key derivation is isomorphism-invariant
  (variable renamings + atom shuffles key identically), faithful (equal
  keys only for genuinely isomorphic queries — the key *is* the canonical
  serialization), idempotent, and TD-numbering-insensitive.  The
  generative half runs under hypothesis when installed; a fixed seed
  corpus drives the same assertions otherwise.
* **plan cache** — isomorphic lookups hit and share one engine; a cached
  plan's results are bit-identical to a cold compile of the same plan;
  LRU eviction honors ``max_plans`` (0 = always-cold regime).
* **sessions** — N client threads streaming a Zipf-mixed query workload
  each match the serial one-shot oracle; the admission bound is never
  exceeded (``in_flight_high_water``); rejection carries a positive
  ``retry_after_s`` and the server recovers; per-session blocking syncs
  stay within the O(op-runs) budget; the worker's syncs do NOT leak into
  client-thread SyncCounters (thread-local scopes).
* **persistence** — a snapshot written by a *separate process* warms a
  fresh server (plan-cache hit + ``tier2_replay_hits > 0`` on its first
  query); truncated / corrupt / wrong-version / wrong-config snapshots
  fall back cold without raising.
* **slab epoch** — importing table state whose slab epoch cannot cover
  its resident payload blocks cold-starts the payload region only
  ("flushed"), keys stay warm, and results remain exact (the stale-splice
  regression this PR's ``import_state`` validation closes).
"""
import dataclasses
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.configs.paper_clftj import TPU_SERVE
from repro.core import choose_plan, cycle_query, engine, path_query
from repro.core.cq import CQ, Atom
from repro.core.db import graph_db
from repro.core.hostsync import SyncCounter
from repro.core.td import TreeDecomposition
from repro.serve import (JoinServer, PlanCache, SessionRejected,
                         canonical_cq, canonical_td)
from repro.serve.canonical import rename_query

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.serve

# small tables so tests stay fast; payloads on so replay paths execute
CFG = dataclasses.replace(TPU_SERVE, cache_slots=512, cache_assoc=4,
                          payload_rows=1 << 13, frontier_capacity=1 << 14)


@pytest.fixture(scope="module")
def db():
    from repro.data.graphs import zipf_graph
    return graph_db(zipf_graph(16, 110, 1.1, seed=314))


def _aligned(res):
    """Result rows with columns sorted by variable name — comparable
    across engines that picked different output orders."""
    idx = [res.order.index(v) for v in sorted(res.order)]
    rows = np.asarray(res.tuples)[:, idx]
    return {tuple(map(int, r)) for r in rows.tolist()}


def _aligned_blocks(order, blocks):
    idx = [order.index(v) for v in sorted(order)]
    if not blocks:
        return set()
    rows = np.concatenate(blocks, axis=0)[:, idx]
    return {tuple(map(int, r)) for r in rows.tolist()}


# ---------------------------------------------------------------------------
# canonical keys
# ---------------------------------------------------------------------------

def _scramble(q: CQ, seed: int) -> CQ:
    """A uniformly random isomorphic copy: rename vars + shuffle atoms."""
    rng = np.random.default_rng(seed)
    variables = list(q.variables)
    names = [f"s{i}" for i in rng.permutation(len(variables))]
    mapping = dict(zip(variables, names))
    atoms = list(rename_query(q, mapping).atoms)
    rng.shuffle(atoms)
    return CQ(tuple(atoms))


def _corpus_query(seed: int) -> CQ:
    rng = np.random.default_rng(seed)
    kind = int(rng.integers(0, 3))
    if kind == 0:
        return path_query(int(rng.integers(2, 6)))
    if kind == 1:
        return cycle_query(int(rng.integers(3, 6)))
    from repro.core import random_graph_query
    return random_graph_query(int(rng.integers(3, 6)), 0.6, seed=seed)


def _check_canonical_invariants(q: CQ, seed: int) -> None:
    canon, pos, key = canonical_cq(q)
    # pos is a bijection onto 0..n-1 and the key is a faithful
    # serialization: renaming q through pos reproduces the canon atoms
    assert sorted(pos.values()) == list(range(len(q.variables)))
    renamed = rename_query(q, {v: f"v{i}" for v, i in pos.items()})
    akey = lambda a: (a.relation, a.vars)
    assert sorted(renamed.atoms, key=akey) == sorted(canon.atoms, key=akey)
    # isomorphism-invariance: any scrambled copy keys identically
    canon2, pos2, key2 = canonical_cq(_scramble(q, seed))
    assert key2 == key
    assert canon2 == canon
    # idempotence: the canonical form is a fixpoint
    canon3, pos3, key3 = canonical_cq(canon)
    assert key3 == key and canon3 == canon
    assert all(pos3[f"v{i}"] == i for i in range(len(q.variables)))


def test_canonical_key_invariant_deterministic_corpus():
    for seed in range(40):
        _check_canonical_invariants(_corpus_query(seed), seed * 7 + 1)


def test_distinct_shapes_key_distinct():
    shapes = [path_query(2), path_query(3), path_query(4), cycle_query(3),
              cycle_query(4), cycle_query(5),
              CQ((Atom("E", ("x", "y")), Atom("E", ("x", "z")))),
              CQ((Atom("R", ("x", "y")), Atom("E", ("y", "z"))))]
    keys = [canonical_cq(q)[2] for q in shapes]
    assert len(set(keys)) == len(keys)


def test_canonical_td_numbering_insensitive(db):
    q = path_query(4)
    td, order = choose_plan(q, db.stats())
    _, pos, _ = canonical_cq(q)
    _, key_a = canonical_td(td, pos)
    # renumber the same tree: reverse the child-visit order
    n = len(td.bags)
    perm = list(range(n))
    if n > 2:
        perm = [0] + list(reversed(range(1, n)))
    inv = {old: new for new, old in enumerate(perm)}
    bags = [td.bags[old] for old in perm]
    parent = [inv[td.parent[old]] if td.parent[old] >= 0 else -1
              for old in perm]
    td2 = TreeDecomposition(bags, parent)
    _, key_b = canonical_td(td2, pos)
    assert key_a == key_b


@pytest.mark.slow
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_canonical_key_invariant_generative():
    @settings(max_examples=150, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
    def prop(qseed, sseed):
        _check_canonical_invariants(_corpus_query(qseed), sseed)

    prop()


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_iso_hit_and_bit_identical_results(db):
    pc = PlanCache(db, CFG, max_plans=8)
    q = path_query(3)
    e1, hit1, pos1 = pc.lookup(q)
    assert not hit1 and len(pc) == 1
    cold = np.concatenate(list(e1.engine.evaluate()), axis=0)
    # an isomorphic copy hits the same entry...
    e2, hit2, pos2 = pc.lookup(_scramble(q, 5))
    assert hit2 and e2 is e1 and len(pc) == 1
    # ...and the warm engine (tier-2 replay active) reproduces the cold
    # pass bit-identically: same rows, same order
    warm = np.concatenate(list(e2.engine.evaluate()), axis=0)
    assert np.array_equal(cold, warm)
    # against a fresh cold compile of the same canonical plan
    from repro.core.cached_frontier import JaxCachedTrieJoin
    fresh = JaxCachedTrieJoin(e1.cq, e1.td, e1.order, db,
                              capacity=CFG.frontier_capacity,
                              dedup=CFG.dedup, impl=CFG.impl,
                              cache=CFG.cache_config(),
                              expand_kernel=CFG.expand_kernel,
                              emit_in_flight=CFG.emit_in_flight)
    ref = np.concatenate(list(fresh.evaluate()), axis=0)
    assert np.array_equal(cold, ref)
    # count mode agrees too (warm cached engine vs cold compile)
    assert e1.engine.count() == fresh.count() == len(ref)


def test_plan_cache_lru_and_cold_regime(db):
    pc = PlanCache(db, CFG, max_plans=1)
    pc.lookup(path_query(2))
    pc.lookup(cycle_query(3))          # evicts the path plan
    assert len(pc) == 1
    _, hit, _ = pc.lookup(path_query(2))
    assert not hit                     # was evicted
    cold = PlanCache(db, CFG, max_plans=0)
    for _ in range(2):
        _, hit, _ = cold.lookup(path_query(2))
        assert not hit
    assert len(cold) == 0


def test_config_keys_separate_plans(db):
    # same query, different engine config → different key space: a plan
    # compiled for one table geometry must not serve another
    from repro.serve import config_key
    other = dataclasses.replace(CFG, cache_slots=CFG.cache_slots * 2)
    assert config_key(CFG) != config_key(other)
    assert PlanCache(db, CFG).cfg_key != PlanCache(db, other).cfg_key


def test_snapshot_carries_autotune_entries(db, tmp_path):
    from repro.kernels import registry
    spec = registry.ExpandSpec(capacity=1 << 30, n_vars=3, n_atoms=2,
                               n_others=1, dtype="int32", x64=True)
    entry = {"spec": dataclasses.asdict(spec), "platform": "serving-test",
             "choice": "xla"}
    assert registry.merge_autotune_entries([entry]) == 1
    try:
        snap = str(tmp_path / "auto.npz")
        with JoinServer(db, CFG) as srv:
            srv.count(path_query(2))
            srv.save_snapshot(snap)
        registry.clear_autotune_cache()
        assert entry not in registry.autotune_entries()
        with JoinServer(db, CFG) as srv:
            summary = srv.load_snapshot(snap)
        assert summary["autotune"] >= 1
        assert entry in registry.autotune_entries()
    finally:
        registry.clear_autotune_cache()


def test_explicit_td_and_auto_key_separate(db):
    pc = PlanCache(db, CFG, max_plans=8)
    q = path_query(3)
    td, order = choose_plan(q, db.stats())
    _, hit_a, _ = pc.lookup(q)
    _, hit_b, _ = pc.lookup(q, td, order)
    assert not hit_a and not hit_b and len(pc) == 2


# ---------------------------------------------------------------------------
# sessions
# ---------------------------------------------------------------------------

def test_concurrent_sessions_match_serial_oracle(db):
    base = [path_query(3), cycle_query(3), path_query(4)]
    rng = np.random.default_rng(99)
    # Zipf-mixed workload of isomorphic variants, one stream per query
    work = []
    for i in range(18):
        j = min(int(rng.zipf(1.8)) - 1, len(base) - 1)
        work.append(_scramble(base[j], 1000 + i))
    # one oracle per *variant*: isomorphic queries share a plan but their
    # labeled answer sets differ (variable roles swap under renaming)
    oracle = {}
    for q in work:
        if q not in oracle:
            oracle[q] = _aligned(engine.evaluate(q, db))
    failures = []
    with JoinServer(db, CFG, max_sessions=3, max_plans=8,
                    block_queue=4) as srv:
        def client(tid, queries):
            for q in queries:
                while True:
                    try:
                        sess = srv.submit(q, "stream")
                        break
                    except SessionRejected as e:
                        threading.Event().wait(min(e.retry_after_s, 0.05))
                blocks = list(sess.blocks())
                res = sess.result(timeout=120)
                got = _aligned_blocks(res.order, blocks)
                if got != oracle[q]:
                    failures.append((tid, q))
                # per-session blocking syncs: O(op runs), never O(chunks)
                r = sess.op_runs
                budget = (3 * r.get("expand", 0) + r.get("fold", 0)
                          + r.get("span", 0) + r.get("emit", 0) + 10)
                if sess.sync.count > budget:
                    failures.append((tid, "sync", sess.sync.count, budget))

        threads = [threading.Thread(target=client, args=(t, work[t::4]))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        assert not failures, failures[:3]
        stats = srv.stats()
    assert stats["in_flight_high_water"] <= 3
    assert stats["completed"] == len(work)
    assert stats["failed"] == 0
    assert stats["plan_cache"]["hits"] >= len(work) - len(base)


def test_admission_bound_rejection_and_recovery(db):
    with JoinServer(db, CFG, max_sessions=2, max_plans=4) as srv:
        srv.count(path_query(3))      # warm the plan first
        # stall the worker at the execution gate so both admitted
        # sessions stay in flight deterministically
        srv._exec_lock.acquire()
        try:
            s1 = srv.submit(path_query(3), "stream")
            s2 = srv.submit(path_query(3), "stream")
            with pytest.raises(SessionRejected) as exc:
                srv.submit(path_query(3), "stream")
            assert exc.value.retry_after_s > 0
            assert srv.stats()["rejected"] == 1
            s2.cancel()               # abandoned while still queued
        finally:
            srv._exec_lock.release()
        rows = sum(b.shape[0] for b in s1.blocks())
        assert rows == s1.result(timeout=120).count
        with pytest.raises(Exception):
            s2.result(timeout=120)
        # slots freed: the server keeps serving
        r = srv.count(path_query(3))
        assert r.count == engine.count(path_query(3), db).count
        assert srv.stats()["in_flight"] == 0


def test_worker_syncs_do_not_leak_into_client_counter(db):
    with JoinServer(db, CFG, max_sessions=2) as srv:
        with SyncCounter() as sc:
            srv.evaluate(path_query(3))
        # execution happens on the worker thread; its device syncs must
        # land in the session's counter, not this thread's
        assert sc.count == 0


def test_session_result_order_uses_client_names(db):
    q = CQ((Atom("E", ("b", "q")), Atom("E", ("z", "b")),
            Atom("E", ("a", "z"))))
    with JoinServer(db, CFG) as srv:
        res = srv.evaluate(q)
        assert set(res.order) == {"a", "b", "q", "z"}
        assert _aligned(res) == _aligned(engine.evaluate(q, db))
        assert res.plan_cache_hit in (False,)  # first query is a miss
        res2 = srv.evaluate(_scramble(q, 3))
        assert res2.plan_cache_hit


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

_WRITER = r"""
import sys
sys.path.insert(0, {src!r})
import numpy as np
from repro.configs.paper_clftj import TPU_SERVE
import dataclasses
from repro.core import path_query
from repro.core.db import graph_db
from repro.core.engine import serve
from repro.serve import save_snapshot
from repro.data.graphs import zipf_graph

CFG = dataclasses.replace(TPU_SERVE, cache_slots=512, cache_assoc=4,
                          payload_rows=1 << 13, frontier_capacity=1 << 14)
db = graph_db(zipf_graph(16, 110, 1.1, seed=314))
with serve(db, CFG) as srv:
    r = srv.evaluate(path_query(3))
    assert r.tuples is not None and len(r.tuples) > 0
    save_snapshot({snap!r}, srv.plan_cache)
print("WROTE")
"""


def test_snapshot_from_other_process_serves_warm(db, tmp_path):
    snap = str(tmp_path / "serve_snap.npz")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _WRITER.format(src=src, snap=snap)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "WROTE" in proc.stdout
    with JoinServer(db, CFG) as srv:
        summary = srv.load_snapshot(snap)
        assert summary["status"] == "ok"
        assert summary["plans"] >= 1 and summary["tables"] >= 1
        assert summary["flushed"] == 0
        # the FIRST query of this process: auto-keyed lookup must hit the
        # loaded plan and replay persisted payload blocks
        q = _scramble(path_query(3), 11)
        res = srv.evaluate(q)
        assert res.plan_cache_hit
        assert res.tier2_replay_hits > 0
        assert _aligned(res) == _aligned(engine.evaluate(q, db))


@pytest.fixture(scope="module")
def warm_snapshot(db, tmp_path_factory):
    """An in-process snapshot with resident payload state, for the
    corruption/fallback tests (cheaper than a subprocess per test)."""
    snap = str(tmp_path_factory.mktemp("serve") / "warm.npz")
    with JoinServer(db, CFG) as srv:
        srv.evaluate(path_query(3))
        srv.evaluate(cycle_query(3))
        srv.save_snapshot(snap)
    return snap


@pytest.mark.parametrize("mangle", ["truncate", "garbage", "version"])
def test_unusable_snapshot_falls_back_cold(db, warm_snapshot, tmp_path,
                                           mangle):
    bad = str(tmp_path / f"bad_{mangle}.npz")
    raw = open(warm_snapshot, "rb").read()
    if mangle == "truncate":
        open(bad, "wb").write(raw[: len(raw) // 3])
    elif mangle == "garbage":
        open(bad, "wb").write(b"\x00\xde\xad\xbe\xef" * 64)
    else:
        import json
        man = {"version": 99, "cfg_key": "", "autotune": [], "plans": []}
        arr = np.frombuffer(json.dumps(man).encode(), np.uint8).copy()
        np.savez_compressed(bad, manifest=arr)
    with JoinServer(db, CFG) as srv:
        with pytest.warns(UserWarning):
            summary = srv.load_snapshot(bad)
        assert summary["status"] == "cold"
        assert summary["plans"] == 0
        res = srv.evaluate(path_query(3))     # cold but fully functional
        assert not res.plan_cache_hit
        assert _aligned(res) == _aligned(engine.evaluate(path_query(3), db))


def test_config_mismatch_transfers_autotune_only(db, warm_snapshot):
    other = dataclasses.replace(CFG, cache_slots=256)
    with JoinServer(db, other) as srv:
        summary = srv.load_snapshot(warm_snapshot)
        assert summary["status"] == "config-mismatch"
        assert summary["plans"] == 0
        res = srv.count(path_query(3))
        assert res.count == engine.count(path_query(3), db).count


def test_snapshot_roundtrip_in_process(db, warm_snapshot):
    with JoinServer(db, CFG) as srv:
        summary = srv.load_snapshot(warm_snapshot)
        assert summary["status"] == "ok"
        assert summary["plans"] == 2 and summary["flushed"] == 0
        res = srv.evaluate(path_query(3))
        assert res.plan_cache_hit and res.tier2_replay_hits > 0


# ---------------------------------------------------------------------------
# slab epoch (eval-mode cold/warm asymmetry regression)
# ---------------------------------------------------------------------------

def _resident_payload_state(pc):
    """(entry, node, state) for some table with resident payload blocks."""
    for entry in pc.entries():
        for node, st in entry.engine.cache.export_state().items():
            pay_len = np.asarray(st.get("pay_len", -1))
            used = np.asarray(st.get("used", False))
            if pay_len.ndim and (used & (pay_len >= 0)).any():
                return entry, node, st
    raise AssertionError("no table with resident payload blocks")


def test_stale_slab_epoch_flushes_payload_only(db):
    pc = PlanCache(db, CFG, max_plans=4)
    entry, _, _ = pc.lookup(path_query(3))
    ref = np.concatenate(list(entry.engine.evaluate()), axis=0)
    entry, node, st = _resident_payload_state(pc)
    tbl = entry.engine.cache.get(node)
    flushes0 = tbl.payload_flushes
    # a snapshot whose epoch was lost: bump says "nothing allocated" while
    # pay_len still claims blocks — the stale-splice hazard
    bad = dict(st)
    bad["slab_bump"] = 0
    assert tbl.import_state(bad) == "flushed"
    assert tbl.payload_flushes == flushes0 + 1
    assert tbl.slab_bump == 0
    # payload region is cold (no block can replay-splice stale rows) but
    # the key/count planes stayed warm and results are exact
    assert int(np.asarray(tbl.pay_len).max()) == -1
    again = np.concatenate(list(entry.engine.evaluate()), axis=0)
    assert np.array_equal(ref, again)


def test_block_past_epoch_also_flushes(db):
    pc = PlanCache(db, CFG, max_plans=4)
    e0, _, _ = pc.lookup(path_query(3))
    list(e0.engine.evaluate())          # populate payload blocks
    entry, node, st = _resident_payload_state(pc)
    tbl = entry.engine.cache.get(node)
    bad = dict(st)
    # claim a block that ends past the allocated prefix
    off = np.array(bad["pay_off"], np.int32, copy=True)
    ln = np.array(bad["pay_len"], np.int32, copy=True)
    used = np.asarray(bad["used"])
    r, w = np.argwhere(used & (ln >= 0))[0]
    off[r, w] = int(bad["slab_bump"])
    ln[r, w] = 4
    bad["pay_off"], bad["pay_len"] = off, ln
    assert tbl.import_state(bad) == "flushed"
    ref = engine.evaluate(path_query(3), db)
    got = np.concatenate(list(entry.engine.evaluate()), axis=0)
    assert len(got) == len(ref.tuples)


def test_rejected_import_leaves_table_unchanged(db):
    pc = PlanCache(db, CFG, max_plans=4)
    entry, _, _ = pc.lookup(path_query(3))
    entry.engine.count()
    states = entry.engine.cache.export_state()
    node, st = next(iter(states.items()))
    tbl = entry.engine.cache.get(node)
    keys0 = np.asarray(tbl.keys).copy()
    bad = dict(st)
    bad["keys"] = np.zeros((3, 3), np.int64)   # wrong geometry
    assert tbl.import_state(bad) == "rejected"
    assert np.array_equal(np.asarray(tbl.keys), keys0)

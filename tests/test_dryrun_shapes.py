"""Dry-run grid definition: 10 archs x 4 shapes = 40 cells; skips recorded
only for long_500k on pure full-attention archs."""
from repro.configs import ARCHS
from repro.launch.shapes import SHAPES, cell_supported


def test_grid_is_40_cells():
    assert len(ARCHS) == 10 and len(SHAPES) == 4


def test_long_context_skips():
    skipped = [(a, s) for a in ARCHS for s in SHAPES
               if not cell_supported(ARCHS[a], s)[0]]
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == set(ARCHS) - {
        "recurrentgemma-2b", "rwkv6-7b"}
    assert len(skipped) == 8


def test_batch_specs_cover_modalities():
    from repro.launch.shapes import batch_specs
    b = batch_specs(ARCHS["llama-3.2-vision-90b"], SHAPES["train_4k"])
    assert "image_embeds" in b
    b = batch_specs(ARCHS["whisper-tiny"], SHAPES["prefill_32k"])
    assert "audio_embeds" in b
    b = batch_specs(ARCHS["rwkv6-7b"], SHAPES["decode_32k"])
    assert b["tokens"].shape == (128, 1)

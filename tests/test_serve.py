"""Serving correctness: prefill + decode chain reproduces the full forward
logits (per family; bf16 KV-cache quantization sets the tolerance)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import Model
from repro.models.kvcache import pad_caches
from repro.models.transformer import forward

FAMS = ["minitron-8b", "qwen2.5-3b", "recurrentgemma-2b",
        "qwen3-moe-235b-a22b", "llama-3.2-vision-90b", "rwkv6-7b",
        "whisper-tiny"]


@pytest.mark.parametrize("name", FAMS)
def test_prefill_decode_matches_forward(name):
    cfg = dataclasses.replace(get_arch(name + "-smoke"),
                              dtype_compute="float32", capacity_factor=8.0)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, T = 2, 12
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, T),
                                          0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_model)) * .1
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_seq, cfg.d_model)) * .1
    logits_full, _ = forward(cfg, params, batch)
    lg, caches = m.prefill(params, {**batch, "tokens": batch["tokens"][:, :6]})
    caches = pad_caches(cfg, caches, T - 6)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, 5]),
                               rtol=2e-3, atol=2e-3)
    for i in range(6, T):
        lg, caches = m.decode(params, caches, batch["tokens"][:, i:i + 1],
                              jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, i]),
            rtol=5e-3, atol=5e-3, err_msg=f"{name} pos {i}")


def test_greedy_generate_shapes():
    from repro.train.serve_step import greedy_generate
    cfg = get_arch("qwen2.5-3b-smoke")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    out = greedy_generate(m, params,
                          {"tokens": jnp.ones((3, 8), jnp.int32)}, steps=5)
    assert out.shape == (3, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab).all()


def test_sliding_window_cache_is_ring():
    """Decoding past the window must evict only out-of-window positions."""
    cfg = dataclasses.replace(get_arch("recurrentgemma-2b-smoke"),
                              dtype_compute="float32", window=8)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, T = 1, 24
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, T),
                                          0, cfg.vocab)}
    logits_full, _ = forward(cfg, params, batch)
    lg, caches = m.prefill(params, {"tokens": batch["tokens"][:, :4]})
    caches = pad_caches(cfg, caches, T - 4)
    for i in range(4, T):
        lg, caches = m.decode(params, caches, batch["tokens"][:, i:i + 1],
                              jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, i]),
            rtol=5e-3, atol=5e-3, err_msg=f"pos {i}")

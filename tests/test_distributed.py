"""Multi-device tests (subprocess: XLA host-device flags must be set before
jax initializes, and the main pytest process must keep 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_join_count_parity():
    out = _run("""
import numpy as np, jax
from repro.core import cycle_query, choose_plan, lftj_count
from repro.core.distributed import make_distributed_count
from repro.core.db import graph_db
rng = np.random.default_rng(5)
db = graph_db(rng.integers(0, 60, size=(400, 2)))
q = cycle_query(4)
td, order = choose_plan(q, db.stats())
mesh = jax.make_mesh((4, 2), ("data", "model"))
fn, eng = make_distributed_count(q, td, order, db, mesh,
                                 capacity=1 << 12, axes=("data", "model"))
with mesh:
    total, ov = fn()
print(int(total), int(ov), lftj_count(q, order, db))
""")
    total, ov, want = map(int, out.split())
    assert total == want and ov == 0


def test_distributed_evaluate_payload_parity_and_warm_replay():
    """Payload-capable distributed evaluation (DESIGN.md §2.8): per-shard
    slab arenas, shard-local splice, host-side merge.  The merged tuple
    set must equal the host oracle's on both passes, and the second pass
    (tables round-tripped) must serve tier-2 replay hits — the
    acceptance-criterion recurring-bag query."""
    out = _run("""
import numpy as np, jax
from repro.core import CacheConfig, bowtie_query, choose_plan, clftj_evaluate
from repro.core.distributed import make_distributed_evaluate
from repro.core.db import graph_db
from repro.data.graphs import zipf_graph
db = graph_db(zipf_graph(14, 80, 1.1, seed=7))
q = bowtie_query()
td, order = choose_plan(q, db.stats())
want = {tuple(map(int, t)) for t in
        np.asarray(clftj_evaluate(q, td, order, db),
                   np.int64).reshape(-1, len(order))}
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = CacheConfig(policy="setassoc", slots=256, assoc=4,
                  cache_payloads=True, payload_rows=1 << 12)
fn0, eng0 = make_distributed_evaluate(q, td, order, db, mesh,
                                      capacity=1 << 12)
assert eng0.cache_config.cache_payloads, "default must be replay-capable"
fn, eng = make_distributed_evaluate(q, td, order, db, mesh,
                                    capacity=1 << 12,
                                    axes=("data", "model"), cache=cfg)
rows1, s1, tables = fn()
rows2, s2, _ = fn(tables)
got1 = {tuple(map(int, r)) for r in rows1.tolist()}
got2 = {tuple(map(int, r)) for r in rows2.tolist()}
print(int(got1 == want and rows1.shape[0] == len(got1)),
      int(got2 == want and rows2.shape[0] == len(got2)),
      s1["overflow"] + s2["overflow"],
      s1["tier2_replay_hits"], s2["tier2_replay_hits"],
      int(s1["count"] == s2["count"] == len(want)))
""")
    ok1, ok2, ov, hits1, hits2, counts_ok = map(int, out.split())
    assert ok1 and ok2 and counts_ok and ov == 0
    assert hits1 == 0 and hits2 > 0, (hits1, hits2)


def test_sharded_train_step_runs_on_mesh():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import Model
from repro.train.train_step import TrainConfig, init_train_state, make_train_step, state_shardings
from repro.sharding import rules as shr
cfg = get_arch('minitron-8b-smoke')
model = Model(cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    state = init_train_state(model, jax.random.PRNGKey(0))
    shards = state_shardings(model, mesh)
    state = jax.device_put(state, shards)
    step = jax.jit(make_train_step(model, TrainConfig(microbatches=2), mesh))
    batch = {"tokens": jnp.ones((8, 16), jnp.int32),
             "targets": jnp.ones((8, 16), jnp.int32)}
    batch = jax.device_put(batch, jax.tree.map(
        lambda _: shr.batch_sharding(mesh, 8), batch))
    state, metrics = step(state, batch)
    print(float(metrics["loss"]))
""")
    assert float(out.strip()) > 0


@pytest.mark.slow
def test_dryrun_cell_production_mesh():
    """One full dry-run cell on the 512-device production mesh + probe."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import lower_cell
rec = lower_cell("whisper-tiny", "train_4k", multi_pod=False)
print(rec["status"], rec["n_devices"],
      rec["roofline"]["useful_flop_ratio"] > 0.005)
""", devices=512)
    status, ndev, ratio_ok = out.split()
    assert status == "ok" and int(ndev) == 256 and ratio_ok == "True"


def test_elastic_restore_different_mesh(tmp_path):
    out = _run(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import Model
from repro.checkpoint.ckpt import CheckpointManager
from repro.runtime.elastic import restore_for_mesh
from repro.train.train_step import init_train_state, state_shardings
cfg = get_arch('qwen2.5-3b-smoke')
model = Model(cfg)
# save under a 2x4 mesh
mesh1 = jax.make_mesh((2, 4), ("data", "model"))
with mesh1:
    state = jax.device_put(init_train_state(model, jax.random.PRNGKey(0)),
                           state_shardings(model, mesh1))
mgr = CheckpointManager(r'{tmp_path}', keep=1, async_save=False)
mgr.save(3, state)
# restore under a 8x1 mesh (elastic re-scale)
mesh2 = jax.make_mesh((8, 1), ("data", "model"))
with mesh2:
    step, restored, _ = restore_for_mesh(mgr, model, mesh2)
a = np.asarray(jax.tree.leaves(state["params"])[0])
b = np.asarray(jax.tree.leaves(restored["params"])[0])
print(step, np.allclose(a, b))
""")
    step, ok = out.split()
    assert int(step) == 3 and ok == "True"

"""Cross-engine differential conformance harness.

A seeded random corpus of CQs (paths, triangles, longer cycles, stars) and
graph databases is pushed through every engine in the repo — brute force,
reference LFTJ/CLFTJ, YTD, and both JAX frontier engines — asserting
identical counts and identical tuple *sets* (Veldhuizen's LFTJ and Free
Join both validate optimized engines against reference executions; this is
that discipline made a fixture).  The JAX CLFTJ additionally runs under
every tier-2 cache policy: by the paper's optionality property, no policy
may change any answer.

The *randomized zoo* extends the corpus with seeded generators — 4-clique,
5-cycle, bowtie, random acyclic CQs — over Zipf-skewed databases (skew is
what makes adhesion keys recur, so it is exactly the regime where the
evaluation-mode row-block cache must prove it never changes a tuple)."""
import numpy as np
import pytest

from repro.core import (Atom, CQ, CacheConfig, bowtie_query, choose_plan,
                        clftj_count, clftj_evaluate, clique_query,
                        cycle_query, lftj_count, lftj_evaluate, path_query,
                        star_query, ytd_count, ytd_evaluate)
from repro.core import engine
from repro.core.bruteforce import brute_force_evaluate
from repro.core.cached_frontier import JaxCachedTrieJoin, jax_clftj_evaluate
from repro.core.db import Database, graph_db
from repro.core.frontier import jax_lftj_count, jax_lftj_evaluate

SEED = 1729
N_DBS = 3

CORPUS = [
    ("path-3", path_query(3)),
    ("path-4", path_query(4)),
    ("triangle", cycle_query(3)),
    ("cycle-4", cycle_query(4)),
    ("cycle-5", cycle_query(5)),
    ("star-2", star_query(2)),
    ("star-3", star_query(3)),
    ("star-4", star_query(4)),
]

CACHE_POLICIES = [
    CacheConfig(policy="direct", slots=128),
    CacheConfig(policy="setassoc", slots=128, assoc=4),
    CacheConfig(policy="costaware", slots=128, assoc=4),
    CacheConfig(policy="setassoc", slots=32, assoc=4, dynamic=True,
                budget=512, min_slots=16, resize_interval=2),
]


# ---------------------------------------------------------------------------
# Randomized zoo: seeded CQ generators + Zipf-skewed databases
# ---------------------------------------------------------------------------

def random_acyclic_query(k: int, seed: int) -> CQ:
    """Seeded random acyclic CQ: a uniform random tree over x1..xk, each
    edge a binary E-atom with coin-flipped direction."""
    rng = np.random.default_rng(seed)
    atoms = []
    for i in range(2, k + 1):
        j = int(rng.integers(1, i))
        pair = (f"x{j}", f"x{i}") if rng.random() < 0.5 else (f"x{i}", f"x{j}")
        atoms.append(Atom("E", pair))
    return CQ(tuple(atoms))


def zipf_graph_db(nv: int, ne: int, a: float, seed: int) -> Database:
    """Graph with Zipf-distributed endpoint popularity (hot vertices make
    adhesion keys recur — the row-block cache's target regime); the skew
    source is shared with the benchmarks (``data.graphs.zipf_graph``)."""
    from repro.data.graphs import zipf_graph
    return graph_db(zipf_graph(nv, ne, a, seed=seed))


ZOO = [
    ("4-clique", clique_query(4)),
    ("5-cycle", cycle_query(5)),
    ("bowtie", bowtie_query()),
    ("rand-acyclic-5", random_acyclic_query(5, seed=11)),
    ("rand-acyclic-6", random_acyclic_query(6, seed=23)),
    ("rand-acyclic-7", random_acyclic_query(7, seed=47)),
]

# every policy, with the row-block payload region on — plus a deliberately
# tiny slab (forced epoch flushes + prefix refusals) and payloads off
ZOO_CACHES = [
    ("off", None),
    ("direct-pay", CacheConfig(policy="direct", slots=128,
                               cache_payloads=True, payload_rows=1 << 12)),
    ("assoc4-pay", CacheConfig(policy="setassoc", slots=128, assoc=4,
                               cache_payloads=True, payload_rows=1 << 12)),
    ("cost4-pay", CacheConfig(policy="costaware", slots=128, assoc=4,
                              cache_payloads=True, payload_rows=1 << 12)),
    ("adaptive-pay", CacheConfig(policy="setassoc", slots=32, assoc=4,
                                 dynamic=True, budget=512, min_slots=16,
                                 resize_interval=2, cache_payloads=True,
                                 payload_rows=1 << 12)),
    ("tiny-slab", CacheConfig(policy="setassoc", slots=128, assoc=4,
                              cache_payloads=True, payload_rows=24)),
]


@pytest.fixture(scope="module")
def corpus_dbs():
    rng = np.random.default_rng(SEED)
    out = []
    for ne, nv in [(25, 7), (60, 10), (140, 16)]:
        out.append(graph_db(rng.integers(0, nv, size=(ne, 2))))
    return out[:N_DBS]


@pytest.fixture(scope="module")
def zoo_dbs():
    return [zipf_graph_db(12, 60, 1.1, seed=SEED + 1),
            zipf_graph_db(18, 90, 0.9, seed=SEED + 2)]


def _tuple_set(rows, order, variables):
    """Rows over `order` columns → set of tuples in q.variables order."""
    idx = [list(order).index(x) for x in variables]
    return {tuple(int(t[i]) for i in idx) for t in rows}


@pytest.mark.tier1
@pytest.mark.parametrize("qname,q", CORPUS, ids=[n for n, _ in CORPUS])
def test_counts_identical_across_engines(corpus_dbs, qname, q):
    for db in corpus_dbs:
        td, order = choose_plan(q, db.stats())
        want = len(brute_force_evaluate(q, db))
        got = {
            "lftj_ref": lftj_count(q, order, db),
            "clftj_ref": clftj_count(q, td, order, db),
            "ytd": ytd_count(q, td, db),
            "lftj_jax": jax_lftj_count(q, order, db, capacity=1 << 10),
            "clftj_jax": JaxCachedTrieJoin(
                q, td, order, db, capacity=1 << 10).count(),
        }
        assert got == {k: want for k in got}, f"{qname}: {got} != {want}"


@pytest.mark.tier1
@pytest.mark.parametrize("qname,q", CORPUS, ids=[n for n, _ in CORPUS])
def test_tuple_sets_identical_across_engines(corpus_dbs, qname, q):
    for db in corpus_dbs[:2]:
        td, order = choose_plan(q, db.stats())
        want = brute_force_evaluate(q, db)
        assert _tuple_set(lftj_evaluate(q, order, db), order,
                          q.variables) == want
        assert _tuple_set(clftj_evaluate(q, td, order, db), order,
                          q.variables) == want
        assert {tuple(map(int, t))
                for t in ytd_evaluate(q, td, db)} == want
        jax_rows = jax_lftj_evaluate(q, order, db, capacity=1 << 10)
        assert _tuple_set(jax_rows.tolist(), order, q.variables) == want
        jax_c_rows = jax_clftj_evaluate(q, td, order, db, capacity=1 << 10)
        assert _tuple_set(jax_c_rows.tolist(), order, q.variables) == want


@pytest.mark.tier1
@pytest.mark.parametrize("cfg", CACHE_POLICIES,
                         ids=["direct", "assoc4", "cost4", "adaptive"])
def test_jax_clftj_evaluate_tuple_sets_every_policy(corpus_dbs, cfg):
    """The full corpus through JAX CLFTJ *evaluation* under each tier-2
    policy config: materialized tuple sets must equal the host CLFTJ
    oracle's (and brute force) — caching may never change an answer, and
    tier-1 replay must reconstruct every deduplicated row block."""
    db = corpus_dbs[1]
    for qname, q in CORPUS:
        td, order = choose_plan(q, db.stats())
        want = brute_force_evaluate(q, db)
        ref = _tuple_set(clftj_evaluate(q, td, order, db), order,
                         q.variables)
        assert ref == want
        rows = jax_clftj_evaluate(q, td, order, db, capacity=1 << 8,
                                  cache=cfg)
        got = _tuple_set(rows.tolist(), order, q.variables)
        assert got == want, f"{qname} under {cfg.policy}"
        # results are set-semantics: replay must emit each tuple exactly
        # once (a duplicated (parent, exit) pair would hide in the set)
        assert rows.shape[0] == len(got), f"{qname}: duplicate rows"


def test_engine_facade_evaluate_jax_backend(corpus_dbs):
    """engine.evaluate(..., algorithm='clftj', backend='jax') is the same
    tuple set as the ref backend, with tier-2 caching enabled."""
    db = corpus_dbs[0]
    for qname, q in CORPUS[:4]:
        res_jax = engine.evaluate(q, db, algorithm="clftj", backend="jax",
                                  capacity=1 << 9,
                                  cache=CacheConfig(policy="setassoc",
                                                    slots=128, assoc=4))
        res_ref = engine.evaluate(q, db, algorithm="clftj", backend="ref")
        got = _tuple_set(res_jax.tuples.tolist(), res_jax.order, q.variables)
        want = _tuple_set(res_ref.tuples.tolist(), res_ref.order,
                          q.variables)
        assert got == want and res_jax.count == res_ref.count, qname
        assert res_jax.plan_s >= 0 and res_jax.exec_s >= 0
        assert res_jax.wall_s >= res_jax.plan_s + res_jax.exec_s - 1e-6


@pytest.mark.tier1
@pytest.mark.parametrize("cfg", CACHE_POLICIES,
                         ids=["direct", "assoc4", "cost4", "adaptive"])
def test_every_cache_policy_conforms(corpus_dbs, cfg):
    """The full corpus through JAX CLFTJ under each tier-2 policy: counts
    must equal brute force regardless of what the cache admits/evicts."""
    db = corpus_dbs[1]
    for qname, q in CORPUS:
        td, order = choose_plan(q, db.stats())
        want = len(brute_force_evaluate(q, db))
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8, cache=cfg)
        assert eng.count() == want, f"{qname} under {cfg.policy}"
        s = eng.stats
        assert s["tier2_hits"] + s["tier2_misses"] == s["tier2_probes"]


# ---------------------------------------------------------------------------
# Randomized zoo (evaluation-mode row-block caching)
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("qname,q", ZOO, ids=[n for n, _ in ZOO])
def test_zoo_tuple_sets_identical_across_engines(zoo_dbs, qname, q):
    """Every engine in the repo over the randomized zoo: identical tuple
    sets against brute force on Zipf-skewed databases."""
    for db in zoo_dbs:
        td, order = choose_plan(q, db.stats())
        want = brute_force_evaluate(q, db)
        assert _tuple_set(lftj_evaluate(q, order, db), order,
                          q.variables) == want, qname
        assert _tuple_set(clftj_evaluate(q, td, order, db), order,
                          q.variables) == want, qname
        assert {tuple(map(int, t))
                for t in ytd_evaluate(q, td, db)} == want, qname
        jax_rows = jax_lftj_evaluate(q, order, db, capacity=1 << 8)
        assert _tuple_set(jax_rows.tolist(), order, q.variables) == want
        jax_c_rows = jax_clftj_evaluate(q, td, order, db, capacity=1 << 8)
        assert _tuple_set(jax_c_rows.tolist(), order, q.variables) == want


@pytest.mark.tier1
@pytest.mark.parametrize("cname,cfg", ZOO_CACHES,
                         ids=[n for n, _ in ZOO_CACHES])
def test_zoo_evaluate_with_row_block_caching(zoo_dbs, cname, cfg):
    """The zoo through JAX CLFTJ evaluation with row-block caching on and
    off, under every policy (plus a slab small enough to force epoch
    flushes): tuple sets must equal the host CLFTJ oracle, each exactly
    once.  Each engine evaluates TWICE — the second pass replays from the
    payload cache (tables persist per engine), so splice-on-hit itself is
    what's being conformance-checked."""
    db = zoo_dbs[0]
    for qname, q in ZOO:
        td, order = choose_plan(q, db.stats())
        want = _tuple_set(clftj_evaluate(q, td, order, db), order,
                          q.variables)
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8,
                                cache=cfg)
        for run in (1, 2):
            blocks = list(eng.evaluate())
            rows = (np.concatenate(blocks, axis=0) if blocks
                    else np.zeros((0, len(order)), np.int32))
            got = _tuple_set(rows.tolist(), order, q.variables)
            assert got == want, f"{qname}/{cname} run {run}"
            assert rows.shape[0] == len(got), \
                f"{qname}/{cname} run {run}: duplicate rows"


@pytest.mark.tier1
@pytest.mark.pallas
@pytest.mark.parametrize("ek", ["xla", "pallas"])
def test_zoo_expand_kernel_forced_each_way(zoo_dbs, ek):
    """The whole randomized zoo with the EXPAND kernel forced to each
    registry path (the fused Pallas kernel runs through the interpreter
    on CPU): counts and materialized tuple sets must equal the host
    CLFTJ oracle, and the stats must show that the forced path — and
    only the forced path — actually ran.  One payload-cache config rides
    along so splice/replay composes with the fused kernel too."""
    db = zoo_dbs[0]
    pay = CacheConfig(policy="setassoc", slots=128, assoc=4,
                      cache_payloads=True, payload_rows=1 << 12)
    other = "pallas" if ek == "xla" else "xla"
    for qname, q in ZOO:
        td, order = choose_plan(q, db.stats())
        want_n = clftj_count(q, td, order, db)
        want = _tuple_set(clftj_evaluate(q, td, order, db), order,
                          q.variables)
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8,
                                expand_kernel=ek)
        assert eng.count() == want_n, f"{qname} count under {ek}"
        assert eng.stats[f"expand_calls_{ek}"] > 0
        assert eng.stats[f"expand_calls_{other}"] == 0
        rows = jax_clftj_evaluate(q, td, order, db, capacity=1 << 8,
                                  expand_kernel=ek)
        got = _tuple_set(rows.tolist(), order, q.variables)
        assert got == want and rows.shape[0] == len(got), \
            f"{qname} evaluate under {ek}"
        rows_p = jax_clftj_evaluate(q, td, order, db, capacity=1 << 8,
                                    cache=pay, expand_kernel=ek)
        assert _tuple_set(rows_p.tolist(), order, q.variables) == want, \
            f"{qname} payload evaluate under {ek}"


@pytest.mark.tier1
@pytest.mark.parametrize("cname,cfg", [ZOO_CACHES[0], ZOO_CACHES[2],
                                       ZOO_CACHES[5]],
                         ids=["off", "assoc4-pay", "tiny-slab"])
def test_zoo_evaluate_stream_reassembles_to_one_shot(zoo_dbs, cname, cfg):
    """The zoo through streaming evaluation, double-pass per engine so
    splice-on-hit streams too: the reassembled ``evaluate_stream`` blocks
    must be *bit-identical, in block order,* to a one-shot ``evaluate``
    of a twin engine (streaming moves the output data plane only — same
    rows, same arrival order, payloads on or off, flush-heavy slab
    included)."""
    db = zoo_dbs[0]
    for qname, q in ZOO:
        td, order = choose_plan(q, db.stats())
        eng_one = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8,
                                    cache=cfg)
        eng_st = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8,
                                   cache=cfg)
        for run in (1, 2):
            one = list(eng_one.evaluate())
            st = list(eng_st.evaluate_stream())
            a = (np.concatenate(one, axis=0) if one
                 else np.zeros((0, len(order)), np.int32))
            b = (np.concatenate(st, axis=0) if st
                 else np.zeros((0, len(order)), np.int32))
            assert np.array_equal(a, b), f"{qname}/{cname} run {run}"


@pytest.mark.tier1
def test_zoo_replay_hits_on_recurring_bags(zoo_dbs):
    """On a recurring-bag query over a skewed DB, the second evaluation
    pass of a shared engine must actually serve tier-2 replay hits (the
    subsystem is on, not silently bypassed), and counts must line up:
    replayed rows never exceed emitted rows' origin count."""
    db = zoo_dbs[0]
    q = bowtie_query()
    td, order = choose_plan(q, db.stats())
    cfg = CacheConfig(policy="setassoc", slots=256, assoc=4,
                      cache_payloads=True, payload_rows=1 << 13)
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8, cache=cfg)
    n1 = sum(b.shape[0] for b in eng.evaluate())
    assert eng.stats["tier2_slab_rows"] > 0, "no blocks were stored"
    first_hits = eng.stats["tier2_replay_hits"]
    n2 = sum(b.shape[0] for b in eng.evaluate())
    assert n2 == n1
    assert eng.stats["tier2_replay_hits"] > first_hits, \
        "second pass did not replay from the payload cache"


@pytest.mark.tier1
def test_engine_facade_replay_hits_stat(zoo_dbs):
    """Result.tier2_replay_hits surfaces the splice count through the
    facade, and a payload run's tuples equal the cache-off run's."""
    db = zoo_dbs[0]
    q = bowtie_query()
    cfg = CacheConfig(policy="setassoc", slots=256, assoc=4,
                      cache_payloads=True, payload_rows=1 << 13)
    res_off = engine.evaluate(q, db, algorithm="clftj", backend="jax",
                              capacity=1 << 7)
    res_on = engine.evaluate(q, db, algorithm="clftj", backend="jax",
                             capacity=1 << 7, cache=cfg)
    got_on = _tuple_set(res_on.tuples.tolist(), res_on.order, q.variables)
    got_off = _tuple_set(res_off.tuples.tolist(), res_off.order,
                         q.variables)
    assert got_on == got_off and res_on.count == res_off.count
    assert res_off.tier2_replay_hits == 0
    assert res_on.counters["tier2_slab_rows"] > 0


def test_conformance_under_tiny_capacity(corpus_dbs):
    """Morsel splitting (capacity ≪ frontier) must not change answers."""
    db = corpus_dbs[2]
    q = cycle_query(4)
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    for cap in (32, 64, 256):
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=cap,
                                cache=CacheConfig(policy="setassoc",
                                                  slots=64, assoc=4))
        assert eng.count() == want

"""Cross-engine differential conformance harness.

A seeded random corpus of CQs (paths, triangles, longer cycles, stars) and
graph databases is pushed through every engine in the repo — brute force,
reference LFTJ/CLFTJ, YTD, and both JAX frontier engines — asserting
identical counts and identical tuple *sets* (Veldhuizen's LFTJ and Free
Join both validate optimized engines against reference executions; this is
that discipline made a fixture).  The JAX CLFTJ additionally runs under
every tier-2 cache policy: by the paper's optionality property, no policy
may change any answer."""
import numpy as np
import pytest

from repro.core import (CacheConfig, choose_plan, clftj_count,
                        clftj_evaluate, cycle_query, lftj_count,
                        lftj_evaluate, path_query, star_query, ytd_count,
                        ytd_evaluate)
from repro.core import engine
from repro.core.bruteforce import brute_force_evaluate
from repro.core.cached_frontier import JaxCachedTrieJoin, jax_clftj_evaluate
from repro.core.db import graph_db
from repro.core.frontier import jax_lftj_count, jax_lftj_evaluate

SEED = 1729
N_DBS = 3

CORPUS = [
    ("path-3", path_query(3)),
    ("path-4", path_query(4)),
    ("triangle", cycle_query(3)),
    ("cycle-4", cycle_query(4)),
    ("cycle-5", cycle_query(5)),
    ("star-2", star_query(2)),
    ("star-3", star_query(3)),
    ("star-4", star_query(4)),
]

CACHE_POLICIES = [
    CacheConfig(policy="direct", slots=128),
    CacheConfig(policy="setassoc", slots=128, assoc=4),
    CacheConfig(policy="costaware", slots=128, assoc=4),
    CacheConfig(policy="setassoc", slots=32, assoc=4, dynamic=True,
                budget=512, min_slots=16, resize_interval=2),
]


@pytest.fixture(scope="module")
def corpus_dbs():
    rng = np.random.default_rng(SEED)
    out = []
    for ne, nv in [(25, 7), (60, 10), (140, 16)]:
        out.append(graph_db(rng.integers(0, nv, size=(ne, 2))))
    return out[:N_DBS]


def _tuple_set(rows, order, variables):
    """Rows over `order` columns → set of tuples in q.variables order."""
    idx = [list(order).index(x) for x in variables]
    return {tuple(int(t[i]) for i in idx) for t in rows}


@pytest.mark.parametrize("qname,q", CORPUS, ids=[n for n, _ in CORPUS])
def test_counts_identical_across_engines(corpus_dbs, qname, q):
    for db in corpus_dbs:
        td, order = choose_plan(q, db.stats())
        want = len(brute_force_evaluate(q, db))
        got = {
            "lftj_ref": lftj_count(q, order, db),
            "clftj_ref": clftj_count(q, td, order, db),
            "ytd": ytd_count(q, td, db),
            "lftj_jax": jax_lftj_count(q, order, db, capacity=1 << 10),
            "clftj_jax": JaxCachedTrieJoin(
                q, td, order, db, capacity=1 << 10).count(),
        }
        assert got == {k: want for k in got}, f"{qname}: {got} != {want}"


@pytest.mark.parametrize("qname,q", CORPUS, ids=[n for n, _ in CORPUS])
def test_tuple_sets_identical_across_engines(corpus_dbs, qname, q):
    for db in corpus_dbs[:2]:
        td, order = choose_plan(q, db.stats())
        want = brute_force_evaluate(q, db)
        assert _tuple_set(lftj_evaluate(q, order, db), order,
                          q.variables) == want
        assert _tuple_set(clftj_evaluate(q, td, order, db), order,
                          q.variables) == want
        assert {tuple(map(int, t))
                for t in ytd_evaluate(q, td, db)} == want
        jax_rows = jax_lftj_evaluate(q, order, db, capacity=1 << 10)
        assert _tuple_set(jax_rows.tolist(), order, q.variables) == want
        jax_c_rows = jax_clftj_evaluate(q, td, order, db, capacity=1 << 10)
        assert _tuple_set(jax_c_rows.tolist(), order, q.variables) == want


@pytest.mark.parametrize("cfg", CACHE_POLICIES,
                         ids=["direct", "assoc4", "cost4", "adaptive"])
def test_jax_clftj_evaluate_tuple_sets_every_policy(corpus_dbs, cfg):
    """The full corpus through JAX CLFTJ *evaluation* under each tier-2
    policy config: materialized tuple sets must equal the host CLFTJ
    oracle's (and brute force) — caching may never change an answer, and
    tier-1 replay must reconstruct every deduplicated row block."""
    db = corpus_dbs[1]
    for qname, q in CORPUS:
        td, order = choose_plan(q, db.stats())
        want = brute_force_evaluate(q, db)
        ref = _tuple_set(clftj_evaluate(q, td, order, db), order,
                         q.variables)
        assert ref == want
        rows = jax_clftj_evaluate(q, td, order, db, capacity=1 << 8,
                                  cache=cfg)
        got = _tuple_set(rows.tolist(), order, q.variables)
        assert got == want, f"{qname} under {cfg.policy}"
        # results are set-semantics: replay must emit each tuple exactly
        # once (a duplicated (parent, exit) pair would hide in the set)
        assert rows.shape[0] == len(got), f"{qname}: duplicate rows"


def test_engine_facade_evaluate_jax_backend(corpus_dbs):
    """engine.evaluate(..., algorithm='clftj', backend='jax') is the same
    tuple set as the ref backend, with tier-2 caching enabled."""
    db = corpus_dbs[0]
    for qname, q in CORPUS[:4]:
        res_jax = engine.evaluate(q, db, algorithm="clftj", backend="jax",
                                  capacity=1 << 9,
                                  cache=CacheConfig(policy="setassoc",
                                                    slots=128, assoc=4))
        res_ref = engine.evaluate(q, db, algorithm="clftj", backend="ref")
        got = _tuple_set(res_jax.tuples.tolist(), res_jax.order, q.variables)
        want = _tuple_set(res_ref.tuples.tolist(), res_ref.order,
                          q.variables)
        assert got == want and res_jax.count == res_ref.count, qname
        assert res_jax.plan_s >= 0 and res_jax.exec_s >= 0
        assert res_jax.wall_s >= res_jax.plan_s + res_jax.exec_s - 1e-6


@pytest.mark.parametrize("cfg", CACHE_POLICIES,
                         ids=["direct", "assoc4", "cost4", "adaptive"])
def test_every_cache_policy_conforms(corpus_dbs, cfg):
    """The full corpus through JAX CLFTJ under each tier-2 policy: counts
    must equal brute force regardless of what the cache admits/evicts."""
    db = corpus_dbs[1]
    for qname, q in CORPUS:
        td, order = choose_plan(q, db.stats())
        want = len(brute_force_evaluate(q, db))
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8, cache=cfg)
        assert eng.count() == want, f"{qname} under {cfg.policy}"
        s = eng.stats
        assert s["tier2_hits"] + s["tier2_misses"] == s["tier2_probes"]


def test_conformance_under_tiny_capacity(corpus_dbs):
    """Morsel splitting (capacity ≪ frontier) must not change answers."""
    db = corpus_dbs[2]
    q = cycle_query(4)
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    for cap in (32, 64, 256):
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=cap,
                                cache=CacheConfig(policy="setassoc",
                                                  slots=64, assoc=4))
        assert eng.count() == want

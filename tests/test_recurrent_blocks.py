"""RWKV6 / RG-LRU: chunked-parallel forms == per-step recurrences."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import rglru as RG
from repro.models import rwkv6 as RW
from repro.models.specs import block_specs, init_params


def _cfg(**kw):
    base = dict(name="t", family="ssm", n_layers=1, d_model=32, n_heads=4,
                n_kv_heads=4, d_ff=64, vocab=64, rwkv_head_dim=8,
                d_rnn=32, block_pattern=("rwkv",), dtype_compute="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_rwkv_chunked_equals_stepwise():
    cfg = _cfg()
    p = init_params(block_specs(cfg, "rwkv"), jax.random.PRNGKey(0))["mix"]
    B, T, D = 2, 70, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D)) * 0.5
    out, S_fin, _ = RW.rwkv_time_mix(cfg, p, x)
    St = jnp.zeros((B, 4, 8, 8))
    sh = jnp.zeros((B, D))
    outs = []
    for t in range(T):
        o, St, sh = RW.rwkv_time_mix_step(cfg, p, x[:, t:t + 1],
                                          state=St, shift_prev=sh)
        outs.append(np.asarray(o)[:, 0])
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(out),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(St), np.asarray(S_fin),
                               rtol=3e-4, atol=3e-4)


def test_rglru_scan_equals_stepwise():
    cfg = _cfg(block_pattern=("rglru",))
    p = init_params(block_specs(cfg, "rglru"), jax.random.PRNGKey(0))["rec"]
    B, T, R = 2, 33, 32
    xc = jax.random.normal(jax.random.PRNGKey(2), (B, T, R)) * 0.5
    h_seq, h_last = RG.rglru_scan(cfg, p, xc, None)
    h = jnp.zeros((B, R))
    outs = []
    for t in range(T):
        step_h, h = RG.rglru_step(cfg, p, xc[:, t:t + 1], h)
        outs.append(np.asarray(step_h)[:, 0])
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last),
                               rtol=1e-5, atol=1e-5)


def test_rglru_block_prefill_then_step():
    cfg = _cfg(block_pattern=("rglru",))
    p = init_params(block_specs(cfg, "rglru"), jax.random.PRNGKey(0))["rec"]
    B, T, D = 1, 12, 32
    x = jax.random.normal(jax.random.PRNGKey(3), (B, T, D)) * 0.5
    full, _ = RG.rglru_block(cfg, p, x)
    cache = {"h": jnp.zeros((B, D)),
             "conv": jnp.zeros((B, cfg.conv_width - 1, D), jnp.bfloat16)}
    pre, cache = RG.rglru_block(cfg, p, x[:, :6], cache=cache)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :6]),
                               rtol=2e-2, atol=2e-2)
    for t in range(6, T):
        o, cache = RG.rglru_block(cfg, p, x[:, t:t + 1], cache=cache)
        np.testing.assert_allclose(np.asarray(o[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-2, atol=2e-2, err_msg=str(t))


def test_rwkv_state_decay_bounded():
    """Clipped decay keeps chunk exponentials finite (DESIGN.md note)."""
    cfg = _cfg()
    p = init_params(block_specs(cfg, "rwkv"), jax.random.PRNGKey(0))["mix"]
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 64, 32)) * 50.0
    out, S, _ = RW.rwkv_time_mix(cfg, p, x)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(S)).all()

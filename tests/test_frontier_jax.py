"""JAX frontier engines == host references (incl. morsel splitting and
cache-tier configurations)."""
import numpy as np
import pytest

from repro.core import (CacheConfig, choose_plan, lftj_count, lftj_evaluate,
                        cycle_query, path_query, lollipop_query)
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.core.frontier import JaxTrieJoin, jax_lftj_count, \
    jax_lftj_evaluate


@pytest.mark.parametrize("qf,cap", [
    (lambda: path_query(4), 64),
    (lambda: cycle_query(4), 1 << 12),
    (lambda: cycle_query(5), 64),
    (lambda: lollipop_query(3, 2), 256),
])
def test_vectorized_lftj_matches_reference(small_graphs, qf, cap):
    q = qf()
    db = small_graphs[1]
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    assert jax_lftj_count(q, order, db, capacity=cap) == want
    ev = jax_lftj_evaluate(q, order, db, capacity=cap)
    ref = sorted(map(tuple, lftj_evaluate(q, order, db)))
    assert sorted(map(tuple, ev.tolist())) == ref


@pytest.mark.parametrize("kwargs", [
    dict(),                                  # both tiers
    dict(cache=CacheConfig(slots=0)),        # tier-1 only
    dict(dedup=False),                       # tier-2 only
    dict(dedup=False, cache=CacheConfig(slots=0)),   # vanilla
])
def test_cached_engine_tiers(small_graphs, kwargs):
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 10, **kwargs)
    assert eng.count() == want


def test_tier1_dedup_collapses_rows(small_graphs):
    q = cycle_query(5)
    db = small_graphs[2]
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 10)
    eng.count()
    assert eng.stats["tier1_rows_collapsed"] > 0


def test_pallas_impl_in_engine(small_graphs):
    """End-to-end count through the Pallas seek kernel (interpret mode)."""
    q = path_query(4)
    db = small_graphs[0]
    td, order = choose_plan(q, db.stats())
    want = lftj_count(q, order, db)
    assert jax_lftj_count(q, order, db, capacity=512, impl="pallas") == want

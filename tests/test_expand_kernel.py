"""Fused-EXPAND kernel subsystem: parity, dispatch, and autotune.

The fused Pallas kernel (interpret mode on CPU — the `pallas` marker
names this tier; see scripts/verify.sh) must be bit-exact with the XLA
op chain on every EXPAND: same ``needed`` total, same compacted valid
prefix (assign/factor/orig/lo/hi).  Both are additionally validated
against the plain-numpy oracle ``kernels/expand/ref.py``.  Invalid tail
rows are garbage in both paths and not part of the contract (every
downstream consumer gates on ``valid``)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import choose_plan, cycle_query, star_query, engine
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.core.db import Database, graph_db
from repro.kernels import registry
from repro.kernels.expand import FusedExpandConfig, expand_ref
from repro.kernels.expand import fused as fused_mod, xla as xla_mod


def _db(seed=5, nv=10, ne=70):
    rng = np.random.default_rng(seed)
    return graph_db(rng.integers(0, nv, size=(ne, 2)))


def _build_pair(eng, d, config=None):
    a = eng.expand_kernel_args(d)
    fx = xla_mod.build(impl="bsearch", **a)
    fp = fused_mod.build(config=config, **a)
    return fx, fp, a


def _assert_parity(Fa, na, Fb, nb, msg=""):
    va, vb = np.asarray(Fa.valid), np.asarray(Fb.valid)
    ka, kb = int(va.sum()), int(vb.sum())
    assert ka == kb, f"{msg}: {ka} != {kb} valid rows"
    assert va[:ka].all() and vb[:kb].all(), f"{msg}: not compacted"
    for f in ("assign", "factor", "orig", "lo", "hi"):
        np.testing.assert_array_equal(
            np.asarray(getattr(Fa, f))[:ka], np.asarray(getattr(Fb, f))[:kb],
            err_msg=f"{msg}: {f}")
    assert int(na) == int(nb), f"{msg}: needed {int(na)} != {int(nb)}"


def _assert_oracle(F, a, Fo, no):
    """Compare a device result against the numpy oracle's row list.

    Only meaningful when ``needed <= C``: past capacity the device paths
    truncate the slot enumeration (the executor morsel-splits before
    ever running an overflowing chunk), while the oracle enumerates
    everything.  Returns whether the comparison ran."""
    if int(no) > F.assign.shape[0]:
        return False
    host = {k: np.asarray(v) for k, v in F._asdict().items()}
    rows, needed = expand_ref(
        host, np.asarray(a["g_col"]), np.asarray(a["g_rs"]),
        [np.asarray(c) for c in a["other_cols"]],
        d=a["d"], g_ai=a["g_ai"], other_ais=a["other_ais"],
        n_rows_g=a["n_rows_g"])
    k = rows["assign"].shape[0]
    vo = np.asarray(Fo.valid)
    assert int(vo.sum()) == k
    for f in ("assign", "factor", "orig", "lo", "hi"):
        np.testing.assert_array_equal(np.asarray(getattr(Fo, f))[:k],
                                      rows[f], err_msg=f)
    assert int(no) == needed
    return True


# ---------------------------------------------------------------------------
# Bit-exact parity, level by level on real engines
# ---------------------------------------------------------------------------

@pytest.mark.pallas
@pytest.mark.tier1
@pytest.mark.parametrize("qname,q", [("5-cycle", cycle_query(5)),
                                     ("star-3", star_query(3))])
def test_fused_matches_xla_and_oracle_level_by_level(qname, q):
    """Walk every depth: the fused kernel, the XLA chain, and the numpy
    oracle agree on the compacted valid prefix and ``needed``; the next
    level continues from the XLA result so all depths see realistic
    frontiers (duplicate keys included — the db has a small domain)."""
    db = _db(seed=11, nv=8, ne=90)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 10)
    with enable_x64():
        F = eng.initial_frontier()
        oracle_checked = 0
        for d in range(eng.n):
            fx, fp, a = _build_pair(eng, d)
            Fx, nx = fx(F)
            Fp, npd = fp(F)
            _assert_parity(Fx, nx, Fp, npd, msg=f"{qname} d={d}")
            oracle_checked += bool(_assert_oracle(F, a, Fp, npd))
            F = Fx
        assert oracle_checked >= 2, "oracle must cover some depths"


@pytest.mark.pallas
def test_empty_frontier():
    db = _db()
    q = cycle_query(3)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 7)
    with enable_x64():
        F = eng.initial_frontier()
        F = F._replace(valid=jnp.zeros_like(F.valid))
        fx, fp, _ = _build_pair(eng, 0)
        Fx, nx = fx(F)
        Fp, npd = fp(F)
        assert int(nx) == 0 and int(npd) == 0
        assert not np.asarray(Fx.valid).any()
        assert not np.asarray(Fp.valid).any()


@pytest.mark.pallas
def test_single_atom_guard_depth():
    """A depth where only the guard atom participates (no membership
    searches at all): star-query leaf variables."""
    db = _db(seed=2)
    q = star_query(4)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 10)
    solo = [d for d in range(eng.n) if len(eng.at_depth[d]) == 1]
    assert solo, "star query must have single-atom depths"
    with enable_x64():
        F = eng.initial_frontier()
        for d in range(eng.n):
            fx, fp, a = _build_pair(eng, d)
            if d in solo:
                assert a["other_ais"] == ()
                Fx, nx = fx(F)
                Fp, npd = fp(F)
                _assert_parity(Fx, nx, Fp, npd, msg=f"solo d={d}")
            F = fx(F)[0]


@pytest.mark.pallas
def test_duplicate_keys_heavy():
    """A two-value domain: every guard run is long and every membership
    window has duplicates — the stable-compaction order must still be
    identical."""
    rng = np.random.default_rng(0)
    db = graph_db(rng.integers(0, 2, size=(40, 2)))
    q = cycle_query(4)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8)
    with enable_x64():
        F = eng.initial_frontier()
        for d in range(eng.n):
            fx, fp, a = _build_pair(eng, d)
            Fx, nx = fx(F)
            Fp, npd = fp(F)
            _assert_parity(Fx, nx, Fp, npd, msg=f"dup d={d}")
            _assert_oracle(F, a, Fp, npd)
            F = Fx


@pytest.mark.pallas
@pytest.mark.parametrize("x64", [False, True], ids=["x32", "x64"])
def test_parity_x64_on_and_off(x64):
    """The fused kernel derives every ref/out dtype from the chunk at
    trace time, so one built fn serves both precisions."""
    db = _db(seed=9)
    q = cycle_query(3)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8)
    ctx = enable_x64() if x64 else _null()
    with ctx:
        F = eng.initial_frontier()
        want_factor = jnp.int64 if x64 else jnp.int32
        assert F.factor.dtype == want_factor
        for d in range(eng.n):
            fx, fp, _ = _build_pair(eng, d)
            Fx, nx = fx(F)
            Fp, npd = fp(F)
            assert Fp.factor.dtype == want_factor
            _assert_parity(Fx, nx, Fp, npd, msg=f"x64={x64} d={d}")
            F = Fx


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


@pytest.mark.pallas
@pytest.mark.parametrize("cap,block_q", [(96, 64), (128, 7), (64, 1024)])
def test_block_q_config_snaps_to_divisor(cap, block_q):
    """block_q is snapped to a divisor of the capacity (gcd), so odd
    capacities and oversized blocks both work."""
    cfg = FusedExpandConfig(block_q=block_q)
    bq = cfg.resolve_block_q(cap)
    assert cap % bq == 0 and bq <= min(block_q, cap) or bq == cap
    db = _db(seed=4)
    q = cycle_query(3)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=cap)
    with enable_x64():
        F = eng.initial_frontier()
        for d in range(eng.n):
            fx, fp, _ = _build_pair(eng, d, config=cfg)
            Fx, nx = fx(F)
            Fp, npd = fp(F)
            _assert_parity(Fx, nx, Fp, npd, msg=f"cap={cap} bq={block_q}")
            F = Fx


# ---------------------------------------------------------------------------
# Dispatch + autotune
# ---------------------------------------------------------------------------

def _spec(eng, d, **over):
    kw = dict(capacity=eng.capacity, n_vars=eng.n, n_atoms=eng.m,
              n_others=len(eng.expand_kernel_args(d)["other_ais"]),
              dtype="int32", x64=True)
    kw.update(over)
    return registry.ExpandSpec(**kw)


def test_auto_dispatch_picks_xla_on_cpu():
    db = _db()
    q = cycle_query(3)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8)
    assert eng.expand_impl(0) == "xla"
    assert registry.select_expand(_spec(eng, 0), mode="auto",
                                  platform="cpu") == "xla"
    # on an accelerator the same spec resolves to the fused kernel
    assert registry.select_expand(_spec(eng, 0, capacity=1 << 9),
                                  mode="auto", platform="tpu",
                                  measure=False) == "pallas"
    with pytest.raises(ValueError):
        registry.select_expand(_spec(eng, 0), mode="nope")
    with pytest.raises(ValueError):
        JaxCachedTrieJoin(q, td, order, db, expand_kernel="nope")


def test_degenerate_spec_takes_xla_even_when_pallas_forced():
    """An empty relation makes the expansion statically empty — never
    worth a kernel launch; the registry routes it to the XLA chain."""
    db = Database({"E": np.zeros((0, 2), np.int64),
                   "R": np.asarray([[0, 1], [1, 2]], np.int64)})
    from repro.core import Atom, CQ
    q = CQ((Atom("E", ("x1", "x2")), Atom("R", ("x1", "x2"))))
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 6,
                            expand_kernel="pallas")
    assert eng.count() == 0
    assert all(v == "xla" for v in eng.expand_paths.values())


def test_pallas_build_failure_falls_back_to_xla(monkeypatch):
    """The always-available fallback must engage at *build* time: the
    registry trace-validates the fused fn (eval_shape), so a kernel that
    cannot trace is recorded in failures() and the engine runs the XLA
    chain instead of dying mid-query."""
    from repro.kernels.expand import fused as fused_real

    def broken_build(**kw):
        def fn(F):
            raise RuntimeError("mosaic lowering exploded")
        return fn

    registry.clear_autotune_cache()
    monkeypatch.setattr(fused_real, "build", broken_build)
    db = _db(seed=29)
    q = cycle_query(3)
    td, order = choose_plan(q, db.stats())
    with pytest.warns(UserWarning, match="falling back to the XLA path"):
        eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 7,
                                expand_kernel="pallas")
        want = engine.count(q, db, td=td, order=order, capacity=1 << 7).count
        assert eng.count() == want
    assert all(v == "xla" for v in eng.expand_paths.values())
    assert registry.failures(), "failure must be recorded"
    registry.clear_autotune_cache()


def test_autotune_measured_caches_choice():
    registry.clear_autotune_cache()
    db = _db(seed=13)
    q = cycle_query(3)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8)
    a = eng.expand_kernel_args(0)
    spec = _spec(eng, 0)
    builders = {
        "xla": lambda: xla_mod.build(impl="bsearch", **a),
        "pallas": lambda: fused_mod.build(**a),
    }
    with enable_x64():
        choice = registry.select_expand(spec, mode="auto", measure=True,
                                        builders=builders, sizes=eng.sizes)
    assert choice in ("pallas", "xla")
    key = (spec, jax.default_backend())
    assert registry.autotune_cache()[key] == choice
    # second call must not re-measure: poison the builders
    boom = {"xla": None, "pallas": None}
    assert registry.select_expand(spec, mode="auto", measure=True,
                                  builders=boom) == choice
    registry.clear_autotune_cache()


@pytest.mark.pallas
@pytest.mark.tier1
def test_fused_is_at_most_two_device_ops():
    """The acceptance bound: the fused path lowers to ≤2 non-metadata
    device ops per EXPAND (the pallas_call + the ``needed`` extraction);
    the XLA chain is an order of magnitude more."""
    db = _db(seed=21)
    q = cycle_query(4)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8)
    with enable_x64():
        F = eng.initial_frontier()
        for d in range(eng.n):
            fx, fp, _ = _build_pair(eng, d)
            n_fused = registry.device_op_count(fp, F)
            n_xla = registry.device_op_count(fx, F)
            assert n_fused <= 2, f"d={d}: fused lowers to {n_fused} ops"
            assert n_xla > n_fused, f"d={d}: xla {n_xla} vs {n_fused}"


# ---------------------------------------------------------------------------
# Facade stats
# ---------------------------------------------------------------------------

@pytest.mark.pallas
def test_result_records_which_path_ran():
    db = _db(seed=17)
    q = cycle_query(4)
    for ek in ("xla", "pallas"):
        res = engine.count(q, db, capacity=1 << 8, expand_kernel=ek)
        paths = res.expand_paths
        assert paths[ek] > 0
        assert paths["pallas" if ek == "xla" else "xla"] == 0
        res_l = engine.count(q, db, algorithm="lftj", capacity=1 << 8,
                             expand_kernel=ek)
        assert res_l.expand_paths[ek] > 0
        assert res_l.count == res.count

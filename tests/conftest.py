"""Shared fixtures.  NOTE: no XLA device-count flags here — smoke tests and
benches must see the single real device; multi-device tests spawn
subprocesses that set XLA_FLAGS themselves (see test_distributed.py)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="session")
def small_graphs():
    """A few deterministic small graph databases."""
    from repro.core.db import graph_db
    rng = np.random.default_rng(0)
    out = []
    for i, (ne, nv) in enumerate([(30, 8), (60, 10), (120, 14)]):
        out.append(graph_db(rng.integers(0, nv, size=(ne, 2))))
    return out

"""Separator enumeration (paper §4.2): exactness, order, no repetition.

Property coverage runs under hypothesis when installed; a deterministic
seed corpus keeps the same assertions running on minimal installs."""
import numpy as np
import pytest

from repro.core.cq import cycle_query, lollipop_query, path_query, \
    random_graph_query
from repro.core.gaifman import gaifman_graph
from repro.core.separators import (brute_force_constrained_separators,
                                   enumerate_constrained_separators,
                                   min_constrained_separator)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False


QUERIES = [path_query(4), path_query(6), cycle_query(5), cycle_query(6),
           lollipop_query(3, 2), random_graph_query(6, 0.5, seed=3)]


@pytest.mark.parametrize("qi", range(len(QUERIES)))
def test_enumeration_matches_bruteforce(qi):
    g = gaifman_graph(QUERIES[qi])
    for csize in (0, 1, 2):
        C = set(sorted(g)[:csize])
        got = list(enumerate_constrained_separators(g, C))
        want = brute_force_constrained_separators(g, C)
        assert set(got) == set(want)
        assert len(got) == len(set(got)), "repetition"
        sizes = [len(s) for s in got]
        assert sizes == sorted(sizes), "must be emitted by increasing size"


@pytest.mark.parametrize("qi", range(len(QUERIES)))
def test_min_oracle_is_exact(qi):
    g = gaifman_graph(QUERIES[qi])
    C = set(sorted(g)[:1])
    want = brute_force_constrained_separators(g, C)
    m = min_constrained_separator(g, C)
    if not want:
        assert m is None
    else:
        assert m is not None and len(m) == len(want[0])


def _check_random_graph(n: int, seed: int) -> None:
    rng = np.random.default_rng(seed)
    q = random_graph_query(n, float(rng.uniform(0.3, 0.8)), seed=seed)
    g = gaifman_graph(q)
    C = set(list(sorted(g))[: int(rng.integers(0, 3))])
    got = list(enumerate_constrained_separators(g, C, max_size=3))
    want = [s for s in brute_force_constrained_separators(g, C, max_size=3)]
    assert set(got) == set(want)


@pytest.mark.parametrize("n,seed", [(4 + s % 4, 101 + s) for s in range(10)])
def test_corpus_enumeration_random_graphs(n, seed):
    _check_random_graph(n, seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(4, 7), st.integers(0, 10_000))
    def test_property_enumeration_random_graphs(n, seed):
        _check_random_graph(n, seed)

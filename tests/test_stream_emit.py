"""Streaming async EMIT + payload-capable static evaluation (DESIGN §2.8).

Four groups:

* the :class:`AsyncFetchQueue` contract — FIFO arrival order, the
  in-flight bound (back-pressure), drain completeness, and the
  SyncCounter accounting split (async issues never count as blocking
  syncs);
* ``evaluate_stream`` vs one-shot ``evaluate``: bit-identical rows in
  identical order, for the vanilla LFTJ engine, the cached engine under
  payload caching, and through the ``engine.evaluate_stream`` facade
  (whose ResultStream must reproduce the one-shot Result totals);
* trace-time ``execute_static`` evaluation: oracle parity, warm-pass
  payload replay (``tier2_replay_hits > 0``), count-table bypass
  (optionality), and honest overflow flagging at tiny capacity —
  including the splice path, which clamps silently and must be
  flagged by the executor;
* the measured-autotune JSON sidecar: save/load roundtrip, in-memory
  precedence, and the corrupt-file → cold-cache fallback.
"""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (AsyncFetchQueue, CacheConfig, SyncCounter,
                        bowtie_query, choose_plan, clftj_count,
                        clftj_evaluate, cycle_query, engine, path_query)
from repro.core.cached_frontier import JaxCachedTrieJoin
from repro.core.db import graph_db
from repro.core.distributed import StaticCLFTJ
from repro.core.frontier import JaxTrieJoin


@pytest.fixture(scope="module")
def db():
    from repro.data.graphs import zipf_graph
    return graph_db(zipf_graph(16, 110, 1.1, seed=314))


PAY = CacheConfig(policy="setassoc", slots=256, assoc=4,
                  cache_payloads=True, payload_rows=1 << 13)


def _tuple_set(rows):
    return {tuple(map(int, r)) for r in np.asarray(rows).tolist()}


# ---------------------------------------------------------------------------
# AsyncFetchQueue
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_async_queue_fifo_bound_and_drain():
    q = AsyncFetchQueue(max_in_flight=3)
    got = []
    for i in range(10):
        got.extend(q.put(jnp.full((4,), i), f"blk{i}"))
        assert q.in_flight <= 3
    got.extend(q.drain())
    assert q.in_flight == 0 and q.issued == 10
    assert q.high_water <= 3
    # FIFO: host values arrive in exact issue order
    assert [int(x[0]) for x in got] == list(range(10))


@pytest.mark.tier1
def test_async_queue_poll_preserves_order():
    q = AsyncFetchQueue(max_in_flight=8)
    for i in range(5):
        assert q.put(jnp.full((2,), i), "b") == []
    out = list(q.poll()) + list(q.drain())
    assert [int(x[0]) for x in out] == list(range(5))


def test_async_queue_rejects_nonpositive_bound():
    with pytest.raises(ValueError):
        AsyncFetchQueue(max_in_flight=0)


@pytest.mark.tier1
def test_async_issues_counted_separately_from_blocking_syncs():
    from repro.core.hostsync import device_get, device_get_async
    with SyncCounter() as sc:
        h = device_get_async(jnp.arange(8), "async-lbl")
        device_get(jnp.arange(8), "blocking-lbl")
        np.testing.assert_array_equal(h.get(), np.arange(8))
    assert sc.count == 1 and sc.async_count == 1
    assert sc.label_counts == {"async-lbl": 1, "blocking-lbl": 1}
    # completion (h.get()) did not add any event
    assert len(sc.events) == 2


# ---------------------------------------------------------------------------
# evaluate_stream vs one-shot evaluate
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_stream_matches_one_shot_identical_order_lftj(db):
    q = cycle_query(4)
    order = sorted(q.variables)
    one = list(JaxTrieJoin(q, order, db, capacity=1 << 8).evaluate())
    st = list(JaxTrieJoin(q, order, db, capacity=1 << 8).evaluate_stream())
    assert np.array_equal(np.concatenate(one), np.concatenate(st))


@pytest.mark.tier1
@pytest.mark.parametrize("cfg", [None, PAY], ids=["nocache", "payload"])
def test_stream_matches_one_shot_cached_engine(db, cfg):
    """Streaming only moves the output data plane: rows, order, count,
    and the tier-2 stats of a double pass must match the one-shot path
    (second pass exercises splice-on-hit through the stream)."""
    q = bowtie_query()
    td, order = choose_plan(q, db.stats())
    eng_one = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8,
                                cache=cfg)
    eng_st = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 8,
                               cache=cfg)
    for run in (1, 2):
        one = list(eng_one.evaluate())
        st = list(eng_st.evaluate_stream())
        a = (np.concatenate(one) if one
             else np.zeros((0, len(order)), np.int32))
        b = (np.concatenate(st) if st
             else np.zeros((0, len(order)), np.int32))
        assert np.array_equal(a, b), f"run {run}"
    if cfg is not None:
        assert eng_st.stats["tier2_replay_hits"] > 0
        assert (eng_st.stats["tier2_replay_hits"]
                == eng_one.stats["tier2_replay_hits"])


@pytest.mark.tier1
def test_stream_respects_emit_in_flight_bound(db):
    """The executor's queue (exposed as ``last_executor.emit_queue``)
    must actually carry every block under the configured bound — a
    regression that ignores ``emit_in_flight`` or bypasses the queue
    fails here, not just in a perf trace."""
    q = path_query(4)
    td, order = choose_plan(q, db.stats())
    eng = JaxCachedTrieJoin(q, td, order, db, capacity=1 << 6,
                            emit_in_flight=2)
    n = sum(b.shape[0] for b in eng.evaluate_stream())
    ex = eng.last_executor
    assert n == clftj_count(q, td, order, db)
    assert ex.emitted_blocks > 2, "workload too small to exercise the bound"
    q_ = ex.emit_queue
    assert q_.max_in_flight == 2
    assert q_.issued == ex.emitted_blocks
    assert 1 <= q_.high_water <= 2, q_.high_water
    assert q_.in_flight == 0  # fully drained


def test_facade_stream_result_totals(db):
    """engine.evaluate_stream: streamed rows == one-shot tuples, and the
    post-exhaustion Result carries identical count + tier-2 counters."""
    q = bowtie_query()
    res = engine.evaluate(q, db, algorithm="clftj", backend="jax",
                          capacity=1 << 8, cache=PAY)
    rs = engine.evaluate_stream(q, db, capacity=1 << 8, cache=PAY)
    assert rs.result is None  # not exhausted yet
    rows = [b for b in rs]
    got = np.concatenate(rows) if rows else np.zeros((0, 1))
    assert _tuple_set(got) == _tuple_set(res.tuples)
    assert rs.result is not None and rs.result.count == res.count
    assert rs.result.tuples is None
    assert rs.result.counters.keys() == res.counters.keys()
    assert rs.result.order == res.order


def test_facade_stream_rejects_host_backends(db):
    with pytest.raises(ValueError, match="JAX"):
        engine.evaluate_stream(bowtie_query(), db, backend="ref")
    with pytest.raises(ValueError, match="JAX"):
        engine.evaluate_stream(bowtie_query(), db, algorithm="ytd")


# ---------------------------------------------------------------------------
# execute_static evaluation (payload-capable)
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("qname,qf", [("bowtie", bowtie_query()),
                                      ("cycle5", cycle_query(5)),
                                      ("path4", path_query(4))],
                         ids=["bowtie", "cycle5", "path4"])
def test_static_evaluate_matches_oracle_cold_and_warm(db, qname, qf):
    td, order = choose_plan(qf, db.stats())
    want = _tuple_set(np.asarray(clftj_evaluate(qf, td, order, db),
                                 np.int64).reshape(-1, len(order)))
    eng = StaticCLFTJ(qf, td, order, db, capacity=1 << 13, cache=PAY)
    rows, stats, tables = eng.evaluate_static()
    assert not stats["overflow"], qname
    assert _tuple_set(rows) == want and rows.shape[0] == len(want), qname
    rows2, stats2, _ = eng.evaluate_static(tables)
    assert _tuple_set(rows2) == want and rows2.shape[0] == len(want), qname
    assert stats2["count"] == stats["count"] == len(want)


@pytest.mark.tier1
def test_static_evaluate_warm_pass_serves_replay_hits(db):
    """The acceptance-criterion path: on a recurring-bag query the warm
    static pass must report tier2_replay_hits > 0 — payload caching is
    genuinely on in trace-time evaluation, not silently bypassed."""
    q = bowtie_query()
    td, order = choose_plan(q, db.stats())
    eng = StaticCLFTJ(q, td, order, db, capacity=1 << 13, cache=PAY)
    _, stats, tables = eng.evaluate_static()
    assert stats["tier2_replay_hits"] == 0  # cold: nothing resident yet
    _, stats2, _ = eng.evaluate_static(tables)
    assert stats2["tier2_replay_hits"] > 0


@pytest.mark.tier1
def test_static_evaluate_bypasses_count_only_tables(db):
    """Optionality: a payloads-off cache config must leave evaluation
    untouched (count tables cannot replay tuples) while staying exact."""
    q = bowtie_query()
    td, order = choose_plan(q, db.stats())
    want = _tuple_set(np.asarray(clftj_evaluate(q, td, order, db),
                                 np.int64).reshape(-1, len(order)))
    cfg = CacheConfig(policy="setassoc", slots=256, assoc=4)  # no payloads
    eng = StaticCLFTJ(q, td, order, db, capacity=1 << 13, cache=cfg)
    tables = None
    for _ in range(2):
        rows, stats, tables = eng.evaluate_static(tables)
        assert _tuple_set(rows) == want
        assert stats["tier2_replay_hits"] == 0


@pytest.mark.tier1
def test_static_evaluate_flags_overflow_on_tiny_capacity(db):
    """No silent truncation: when the result cannot fit the fixed chunk,
    the overflow flag must be set — on the cold pass (replay overflow)
    AND the warm pass (splice overflow, which the jitted splice step
    clamps without telling)."""
    q = bowtie_query()
    td, order = choose_plan(q, db.stats())
    want_n = clftj_count(q, td, order, db)
    cap = 1 << 6
    assert want_n > cap, "fixture too small to force overflow"
    eng = StaticCLFTJ(q, td, order, db, capacity=cap, cache=PAY)
    _, stats, tables = eng.evaluate_static()
    assert stats["overflow"]
    _, stats2, _ = eng.evaluate_static(tables)
    assert stats2["overflow"]


@pytest.mark.tier1
def test_static_evaluate_dedup_off_conforms(db):
    """Tier-1 off: duplicate adhesion keys must still store exactly one
    block each (the in-trace first-occurrence collapse), with exact
    tuples both passes."""
    q = bowtie_query()
    td, order = choose_plan(q, db.stats())
    want = _tuple_set(np.asarray(clftj_evaluate(q, td, order, db),
                                 np.int64).reshape(-1, len(order)))
    eng = StaticCLFTJ(q, td, order, db, capacity=1 << 13, cache=PAY,
                      dedup=False)
    tables = None
    for _ in range(2):
        rows, stats, tables = eng.evaluate_static(tables)
        assert not stats["overflow"]
        assert _tuple_set(rows) == want and rows.shape[0] == len(want)


# ---------------------------------------------------------------------------
# measured-autotune sidecar persistence
# ---------------------------------------------------------------------------

@pytest.fixture
def fresh_registry():
    from repro.kernels import registry
    saved = registry.autotune_cache()
    registry.clear_autotune_cache()
    yield registry
    registry.clear_autotune_cache()
    registry._AUTOTUNE.update(saved)


def _spec(registry, cap=512):
    return registry.ExpandSpec(capacity=cap, n_vars=3, n_atoms=3,
                               n_others=1, dtype="int32", x64=True)


def _measured(registry, key, choice):
    """Record a decision as if a timing run produced it (only measured
    decisions persist — the heuristic defaults stay process-local)."""
    registry._AUTOTUNE[key] = choice
    registry._MEASURED.add(key)


@pytest.mark.tier1
def test_autotune_sidecar_roundtrip(fresh_registry, tmp_path):
    registry = fresh_registry
    path = str(tmp_path / "autotune.json")
    _measured(registry, (_spec(registry), "tpu"), "pallas")
    _measured(registry, (_spec(registry, cap=1024), "cpu"), "xla")
    assert registry.save_autotune_cache(path) == path
    registry.clear_autotune_cache()
    assert registry.autotune_cache() == {}
    assert registry.load_autotune_cache(path) == 2
    assert registry.autotune_cache()[(_spec(registry), "tpu")] == "pallas"
    assert registry.autotune_cache()[
        (_spec(registry, cap=1024), "cpu")] == "xla"


def test_autotune_sidecar_in_memory_wins(fresh_registry, tmp_path):
    registry = fresh_registry
    path = str(tmp_path / "autotune.json")
    key = (_spec(registry), "tpu")
    _measured(registry, key, "pallas")
    registry.save_autotune_cache(path)
    registry.clear_autotune_cache()
    _measured(registry, key, "xla")  # this process re-measured
    assert registry.load_autotune_cache(path) == 0
    assert registry.autotune_cache()[key] == "xla"


def test_autotune_sidecar_never_persists_heuristics_or_clobbers(
        fresh_registry, tmp_path):
    """Unmeasured (platform-default) decisions must not be written — a
    persisted guess would pre-empt a later measure=True run — and a save
    merges the on-disk entries (in-memory wins), so concurrent processes
    can never clobber each other's measurements."""
    registry = fresh_registry
    path = str(tmp_path / "autotune.json")
    key_a = (_spec(registry), "tpu")
    _measured(registry, key_a, "pallas")
    registry.save_autotune_cache(path)
    registry.clear_autotune_cache()
    # a heuristic-only cache: the save merges the file's measured entry
    # back in and re-writes it — the heuristic itself never lands
    heuristic_key = (_spec(registry, cap=128), "cpu")
    registry._AUTOTUNE[heuristic_key] = "xla"
    registry.save_autotune_cache(path)
    registry.clear_autotune_cache()
    assert registry.load_autotune_cache(path) == 1  # original entry intact
    assert key_a in registry.autotune_cache()
    assert heuristic_key not in registry.autotune_cache()
    # save with no path configured and nothing measured stays a no-op
    registry.clear_autotune_cache()
    assert registry.save_autotune_cache(str(tmp_path / "new.json")) is None
    # concurrent-writer simulation: B measures Y with A's entry on disk;
    # B's write-through must preserve A's measurement
    registry.clear_autotune_cache()
    key_b = (_spec(registry, cap=2048), "gpu")
    _measured(registry, key_b, "xla")
    registry.save_autotune_cache(path)
    registry.clear_autotune_cache()
    assert registry.load_autotune_cache(path) == 2
    assert registry.autotune_cache()[key_a] == "pallas"
    assert registry.autotune_cache()[key_b] == "xla"


@pytest.mark.tier1
def test_autotune_sidecar_corrupt_file_falls_back(fresh_registry, tmp_path):
    """A broken sidecar is a cold cache, never a crash: truncated JSON,
    wrong schema, and per-entry garbage all degrade gracefully."""
    registry = fresh_registry
    path = str(tmp_path / "autotune.json")
    with open(path, "w") as f:
        f.write('{"version": 1, "entries": [{"spec":')  # truncated
    with pytest.warns(UserWarning, match="autotune sidecar"):
        assert registry.load_autotune_cache(path) == 0
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": "nope"}, f)
    with pytest.warns(UserWarning, match="autotune sidecar"):
        assert registry.load_autotune_cache(path) == 0
    # bad entries are skipped individually; good ones still load
    good = {"spec": {"capacity": 256, "n_vars": 2, "n_atoms": 2,
                     "n_others": 1, "dtype": "int32", "x64": False},
            "platform": "gpu", "choice": "pallas"}
    bad_choice = dict(good, choice="cuda")
    bad_spec = {"spec": {"capacity": 1}, "platform": "gpu",
                "choice": "xla"}
    with open(path, "w") as f:
        json.dump({"version": 1,
                   "entries": [bad_spec, bad_choice, good, 7]}, f)
    assert registry.load_autotune_cache(path) == 1
    key = (registry.ExpandSpec(capacity=256, n_vars=2, n_atoms=2,
                               n_others=1, dtype="int32", x64=False), "gpu")
    assert registry.autotune_cache()[key] == "pallas"
    # a missing file is silent (no warning, no entries)
    assert registry.load_autotune_cache(str(tmp_path / "absent.json")) == 0


def test_autotune_env_var_autoload_and_heuristic_hygiene(fresh_registry,
                                                         tmp_path,
                                                         monkeypatch):
    """$REPRO_AUTOTUNE_CACHE: select_expand consults the sidecar before
    deciding, and heuristic (unmeasured) resolutions never leak into it."""
    registry = fresh_registry
    path = str(tmp_path / "autotune.json")
    key_spec, platform = _spec(registry), "tpu"
    _measured(registry, (key_spec, platform), "pallas")
    registry.save_autotune_cache(path)
    registry.clear_autotune_cache()
    monkeypatch.setenv(registry.AUTOTUNE_CACHE_ENV, path)
    # loaded lazily at the first auto dispatch: no measurement happens
    # (builders=None would otherwise pick the platform default)
    got = registry.select_expand(key_spec, mode="auto", platform=platform,
                                 measure=False)
    assert got == "pallas"  # the persisted decision, not the cpu default
    # a heuristic decision for a new spec stays process-local: the
    # sidecar keeps exactly the one measured entry
    spec2 = registry.ExpandSpec(capacity=64, n_vars=2, n_atoms=2,
                                n_others=0, dtype="int32", x64=False)
    assert registry.select_expand(spec2, mode="auto", platform="cpu",
                                  measure=False) == "xla"
    registry.clear_autotune_cache()
    monkeypatch.delenv(registry.AUTOTUNE_CACHE_ENV)
    assert registry.load_autotune_cache(path) == 1

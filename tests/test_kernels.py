"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; see DESIGN.md §2 for the TPU tiling rationale).
Bounded search goes through the kernel registry — the single entry-point
convention (the former ``kernels/leapfrog/ops.py`` facade)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.flash_attention import ops as fa_ops


@pytest.mark.pallas
@pytest.mark.parametrize("n,m", [(0, 4), (1, 1), (7, 5), (100, 64),
                                 (1000, 513), (4096, 700)])
@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_leapfrog_bounds_sweep(n, m, dtype):
    rng = np.random.default_rng(n * 1000 + m)
    col = np.sort(rng.integers(0, max(2 * n, 4), size=n)).astype(dtype)
    v = rng.integers(-3, max(2 * n, 4) + 3, size=m).astype(dtype)
    lo = rng.integers(0, n + 1, size=m).astype(np.int32)
    hi = np.minimum(n, lo + rng.integers(0, n + 1, size=m)).astype(np.int32)
    want_l = np.array([lo[i] + np.searchsorted(col[lo[i]:hi[i]], v[i], "left")
                       for i in range(m)])
    want_u = np.array([lo[i] + np.searchsorted(col[lo[i]:hi[i]], v[i],
                                               "right") for i in range(m)])
    for impl in ("bsearch", "pallas", "ref"):
        got_l = np.asarray(registry.lower_bound(
            jnp.asarray(col), jnp.asarray(v), jnp.asarray(lo),
            jnp.asarray(hi), impl=impl))
        got_u = np.asarray(registry.upper_bound(
            jnp.asarray(col), jnp.asarray(v), jnp.asarray(lo),
            jnp.asarray(hi), impl=impl))
        np.testing.assert_array_equal(got_l, want_l, err_msg=impl)
        np.testing.assert_array_equal(got_u, want_u, err_msg=impl)


CASES = [
    # b, t, s, h, hkv, dh, causal, window, q_offset
    (1, 8, 8, 4, 2, 16, True, None, 0),
    (2, 16, 16, 4, 4, 32, True, None, 0),
    (1, 8, 24, 4, 1, 16, True, None, 16),
    (2, 32, 32, 6, 2, 16, True, 8, 0),
    (1, 16, 16, 4, 2, 16, False, None, 0),
    (2, 1, 40, 8, 2, 64, True, None, 39),
    (1, 24, 24, 2, 2, 128, True, 16, 0),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    b, t, s, h, hkv, dh, causal, window, qoff = case
    rng = np.random.default_rng(hash(case) % 2 ** 31)
    q = jnp.asarray(rng.standard_normal((b, t, h, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), dtype)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    want = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=qoff, impl="ref")
    for impl, kw in (("xla", dict(block_q=8, block_k=8)),
                     ("pallas", dict(block_q=8, block_k=8))):
        got = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                     q_offset=qoff, impl=impl, **kw)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol, err_msg=f"{impl} {case}")


def test_flash_gradients_match_ref():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 16, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 16, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 16, 2, 16)), jnp.float32)
    g_ref = jax.grad(lambda q: fa_ops.flash_attention(
        q, k, v, impl="ref").sum())(q)
    g_xla = jax.grad(lambda q: fa_ops.flash_attention(
        q, k, v, impl="xla", block_q=8, block_k=8).sum())(q)
    np.testing.assert_allclose(np.asarray(g_xla), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


def test_unrolled_equals_scanned():
    """cost-probe mode (xla_unroll) must be numerically identical."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 32, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.float32)
    a = fa_ops.flash_attention(q, k, v, impl="xla", block_q=8, block_k=8)
    b = fa_ops.flash_attention(q, k, v, impl="xla_unroll",
                               block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)

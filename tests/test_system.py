"""End-to-end behaviour of the paper's system: plan -> cached execution ->
same answer as vanilla, with fewer memory accesses on skewed data."""
import numpy as np

from repro.core import (CachePolicy, Counters, choose_plan, clftj_count,
                        lftj_count, cycle_query, engine)
from repro.data.graphs import dataset


def test_end_to_end_clftj_beats_lftj_on_skewed_data():
    db = dataset("wiki-vote-like")
    q = cycle_query(4)
    td, order = choose_plan(q, db.stats())
    c_l, c_c = Counters(), Counters()
    n_l = lftj_count(q, order, db, c_l)
    n_c = clftj_count(q, td, order, db, CachePolicy(), c_c)
    assert n_l == n_c > 0
    # the paper's core claim: caching cuts memory traffic on skewed data
    assert c_c.mem_accesses < c_l.mem_accesses
    assert c_c.cache_hits > 0


def test_engine_facade_roundtrip():
    db = dataset("gnutella-like")
    q = cycle_query(4)
    res_jax = engine.count(q, db)
    res_ref = engine.count(q, db, backend="ref")
    res_lftj = engine.count(q, db, algorithm="lftj", backend="ref")
    assert res_jax.count == res_ref.count == res_lftj.count

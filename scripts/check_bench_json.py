#!/usr/bin/env python
"""Schema check for ``BENCH_*.json`` perf records (CI bench-smoke gate).

``benchmarks/run.py --json`` is the repo's perf-trajectory writer; if its
record shape rots silently, every committed ``BENCH_<date>.json`` after
that is garbage.  This validator pins the contract (stdlib-only — no
jsonschema dependency in CI):

* top level: ``date`` (ISO day), ``modules`` (non-empty str list),
  ``platform``/``jax``/``backend`` (str), ``errors`` (list — must be
  EMPTY in strict mode: a module that crashed mid-bench is a failed
  gate, not a data point), ``rows`` (non-empty record list);
* every row: ``name`` (str), ``us_per_call`` (finite number >= 0),
  ``derived`` (str), plus free-form typed extras.

Usage: ``check_bench_json.py PATH [--allow-errors]`` — exit 0 iff valid.
"""
from __future__ import annotations

import json
import math
import re
import sys
from typing import List

DATE_RE = re.compile(r"^\d{4}-\d{2}-\d{2}$")
TOP_KEYS = {"date", "modules", "platform", "jax", "backend", "errors",
            "rows"}


def validate(payload: object, allow_errors: bool = False) -> List[str]:
    """Returns a list of violations (empty = valid)."""
    bad: List[str] = []
    if not isinstance(payload, dict):
        return [f"top level must be an object, got {type(payload).__name__}"]
    missing = TOP_KEYS - set(payload)
    if missing:
        bad.append(f"missing top-level keys: {sorted(missing)}")
        return bad
    if not (isinstance(payload["date"], str)
            and DATE_RE.match(payload["date"])):
        bad.append(f"date must be YYYY-MM-DD, got {payload['date']!r}")
    if not (isinstance(payload["modules"], list) and payload["modules"]
            and all(isinstance(m, str) for m in payload["modules"])):
        bad.append("modules must be a non-empty list of strings")
    for k in ("platform", "jax", "backend"):
        if not isinstance(payload[k], str) or not payload[k]:
            bad.append(f"{k} must be a non-empty string")
    if not isinstance(payload["errors"], list):
        bad.append("errors must be a list")
    elif payload["errors"] and not allow_errors:
        bad.append(f"bench modules raised: {payload['errors']}")
    rows = payload["rows"]
    if not (isinstance(rows, list) and rows):
        bad.append("rows must be a non-empty list")
        return bad
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            bad.append(f"rows[{i}] must be an object")
            continue
        if not isinstance(row.get("name"), str) or not row.get("name"):
            bad.append(f"rows[{i}].name must be a non-empty string")
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or isinstance(us, bool) \
                or not math.isfinite(us) or us < 0:
            bad.append(f"rows[{i}].us_per_call must be a finite number "
                       f">= 0, got {us!r}")
        if not isinstance(row.get("derived"), str):
            bad.append(f"rows[{i}].derived must be a string")
    return bad


def main(argv: List[str]) -> int:
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 2
    path = argv[0]
    allow_errors = "--allow-errors" in argv[1:]
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL {path}: unreadable ({e})")
        return 1
    bad = validate(payload, allow_errors=allow_errors)
    if bad:
        for b in bad:
            print(f"FAIL {path}: {b}")
        return 1
    print(f"OK {path}: {len(payload['rows'])} rows from "
          f"{len(payload['modules'])} modules on {payload['backend']}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

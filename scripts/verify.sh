#!/usr/bin/env bash
# Tier-1 verify: the ROADMAP command, minus the slow-marked sweeps.
# Usage: scripts/verify.sh [extra pytest args]
#   scripts/verify.sh -m tier1     # quick pre-flight (core invariants only)
#   scripts/verify.sh --pallas     # kernel-parity tier only: the fused
#                                  # Pallas kernels through the interpreter
#                                  # on CPU — tier-1 never needs an
#                                  # accelerator (DESIGN.md §2.7)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "${1:-}" = "--pallas" ]; then
    shift
    exec python -m pytest -x -q -m pallas "$@"
fi
exec python -m pytest -x -q -m "not slow" "$@"

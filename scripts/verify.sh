#!/usr/bin/env bash
# Tier-1 verify: the ROADMAP command, minus the slow-marked sweeps.
# Usage: scripts/verify.sh [extra pytest args]
#   scripts/verify.sh -m tier1     # quick pre-flight (core invariants only)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q -m "not slow" "$@"

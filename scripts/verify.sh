#!/usr/bin/env bash
# Tiered verify: the ROADMAP command, minus the slow-marked sweeps.
# CI (.github/workflows/ci.yml) runs these SAME tiers — one command per
# job, so local pre-flight and the gate can never drift.
# Usage: scripts/verify.sh [extra pytest args]
#   scripts/verify.sh -m tier1       # quick pre-flight (core invariants only)
#   scripts/verify.sh --pallas       # kernel-parity tier only: the fused
#                                    # Pallas kernels through the interpreter
#                                    # on CPU — tier-1 never needs an
#                                    # accelerator (DESIGN.md §2.7)
#   scripts/verify.sh --bench-smoke  # bench-record gate: run the tiny
#                                    # streaming-emit bench config with
#                                    # --json and schema-check the emitted
#                                    # record (scripts/check_bench_json.py)
#                                    # so BENCH_*.json can't silently rot
#   scripts/verify.sh --serve        # query-serving tier only: plan cache,
#                                    # cross-process snapshots, concurrent
#                                    # sessions (DESIGN.md §2.9)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "${1:-}" = "--pallas" ]; then
    shift
    exec python -m pytest -x -q -m pallas "$@"
fi
if [ "${1:-}" = "--serve" ]; then
    shift
    exec python -m pytest -x -q -m "serve and not slow" "$@"
fi
if [ "${1:-}" = "--bench-smoke" ]; then
    shift
    out="$(mktemp -t bench_smoke_XXXXXX.json)"
    trap 'rm -f "$out"' EXIT
    python -m benchmarks.run --only stream_emit --json "$out" "$@"
    python scripts/check_bench_json.py "$out"
    exit 0  # set -e already exited on failure; don't fall through to pytest
fi
exec python -m pytest -x -q -m "not slow" "$@"
